"""Data pipeline + Wigner-rotation property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st

from repro.data.synthetic import make_world, next_day_ground_truth


# ---------------------------------------------------------------------------
# synthetic world
# ---------------------------------------------------------------------------

def test_world_shapes_and_determinism():
    w1 = make_world(n_users=100, n_items=150, seed=3)
    w2 = make_world(n_users=100, n_items=150, seed=3)
    np.testing.assert_array_equal(w1.day0.item_id, w2.day0.item_id)
    assert w1.user_feat.shape == (100, 64)
    assert w1.day0.n_users == 100
    assert (w1.day1.timestamp > 86400.0 - 1e-6).all()


def test_world_has_popularity_skew():
    w = make_world(n_users=400, n_items=600, seed=0)
    counts = np.bincount(w.day0.item_id, minlength=600)
    top = np.sort(counts)[::-1]
    assert top[:30].sum() > counts.sum() * 0.15     # head concentration


def test_next_day_ground_truth_csr():
    w = make_world(n_users=50, n_items=60, seed=1)
    u, it, starts, ends = next_day_ground_truth(w)
    for uid in (0, 10, 49):
        mine = it[starts[uid]:ends[uid]]
        truth = w.day1.item_id[w.day1.user_id == uid]
        assert sorted(mine.tolist()) == sorted(truth.tolist())


# ---------------------------------------------------------------------------
# edge dataset
# ---------------------------------------------------------------------------

def test_legacy_batch_shapes_and_determinism(tiny_dataset, tiny_cfg):
    per = {"uu": 8, "ui": 8, "ii": 8}
    b1 = tiny_dataset.sample_batch(5, 0, per, format="legacy")
    b2 = tiny_dataset.sample_batch(5, 0, per, format="legacy")
    for et in ("uu", "ui", "ii"):
        np.testing.assert_array_equal(b1[et]["src_ids"], b2[et]["src_ids"])
        assert b1[et]["src"]["feat"].shape == (8, 64)
        assert b1[et]["src"]["unbr_feat"].shape == (8, tiny_cfg.k_train, 64)
    b3 = tiny_dataset.sample_batch(6, 0, per, format="legacy")
    assert not np.array_equal(b1["ui"]["src_ids"], b3["ui"]["src_ids"])


def test_dedup_batch_structure_and_determinism(tiny_dataset, tiny_cfg):
    per = {"uu": 8, "ui": 8, "ii": 8}
    b1 = tiny_dataset.sample_batch(5, 0, per)            # default: dedup
    b2 = tiny_dataset.sample_batch(5, 0, per)
    assert set(b1) == {"nodes", "edges"}
    k = tiny_cfg.k_train
    for t in ("user", "item"):
        side = b1["nodes"][t]
        U, E = side["feat"].shape[0], side["unbr_idx"].shape[0]
        assert U % tiny_dataset.pad_multiple == 0
        assert E % tiny_dataset.pad_multiple == 0 and E <= U
        assert side["unbr_idx"].shape == (E, k)
        assert side["unbr_idx"].max() < b1["nodes"]["user"]["feat"].shape[0]
        assert side["inbr_idx"].max() < b1["nodes"]["item"]["feat"].shape[0]
        np.testing.assert_array_equal(side["feat"],
                                      b2["nodes"][t]["feat"])
    for et in ("uu", "ui", "ii"):
        e1 = b1["edges"][et]
        np.testing.assert_array_equal(e1["src_ids"],
                                      b2["edges"][et]["src_ids"])
        # gather maps point at the pack rows holding the edge endpoints
        st, dt = ("user", "user") if et == "uu" else \
            (("user", "item") if et == "ui" else ("item", "item"))
        nu = tiny_dataset.tables.n_users
        off_s = 0 if st == "user" else nu
        off_d = 0 if dt == "user" else nu
        feat_s = (tiny_dataset.user_feat if st == "user"
                  else tiny_dataset.item_feat)
        np.testing.assert_array_equal(
            b1["nodes"][st]["feat"][e1["src_map"]],
            feat_s[e1["src_ids"] - off_s])
        feat_d = (tiny_dataset.user_feat if dt == "user"
                  else tiny_dataset.item_feat)
        np.testing.assert_array_equal(
            b1["nodes"][dt]["feat"][e1["dst_map"]],
            feat_d[e1["dst_ids"] - off_d])


def test_id_only_batch_matches_feat_batch(tiny_dataset):
    per = {"uu": 8, "ui": 8, "ii": 8}
    bf = tiny_dataset.sample_batch(2, 0, per, format="dedup")
    bi = tiny_dataset.sample_batch(2, 0, per, format="dedup_ids")
    for t, table in (("user", tiny_dataset.user_feat),
                     ("item", tiny_dataset.item_feat)):
        assert "feat" not in bi["nodes"][t]
        np.testing.assert_array_equal(table[bi["nodes"][t]["ids"]],
                                      bf["nodes"][t]["feat"])
        for key in ("unbr_idx", "unbr_mask", "inbr_idx", "inbr_mask"):
            np.testing.assert_array_equal(bi["nodes"][t][key],
                                          bf["nodes"][t][key])


def test_expand_batch_round_trips_features(tiny_dataset):
    per = {"uu": 8, "ui": 8, "ii": 8}
    b = tiny_dataset.sample_batch(4, 0, per)
    legacy = tiny_dataset.expand_batch(b)
    nu = tiny_dataset.tables.n_users
    for et in ("uu", "ui", "ii"):
        sub = legacy[et]
        assert sub["src"]["feat"].shape[0] == 8
        assert sub["src"]["unbr_mask"].shape == sub["src"]["unbr_feat"].shape[:2]
        # endpoint features come back exactly
        sid = sub["src_ids"]
        table = (tiny_dataset.user_feat if et != "ii"
                 else tiny_dataset.item_feat)
        off = 0 if et != "ii" else nu
        np.testing.assert_array_equal(sub["src"]["feat"], table[sid - off])
        # masked neighbor features are zeroed like the legacy gather
        m = sub["src"]["unbr_mask"][..., None]
        assert (np.abs(sub["src"]["unbr_feat"] * (1 - m)) == 0).all()


def test_dedup_batch_single_edge_type(tiny_dataset):
    """A type with zero endpoints still packs its neighbor-only rows
    (uu-only batches reference item neighbors and vice versa)."""
    for per in ({"uu": 8}, {"ii": 8}, {"ui": 8}):
        b = tiny_dataset.sample_batch(1, 0, per)
        (et,) = per
        assert set(b["edges"]) == {et}
        for t in ("user", "item"):
            side = b["nodes"][t]
            assert side["unbr_idx"].max() < \
                b["nodes"]["user"]["feat"].shape[0]
            assert side["inbr_idx"].max() < \
                b["nodes"]["item"]["feat"].shape[0]
        legacy = tiny_dataset.expand_batch(b)
        assert legacy[et]["src"]["feat"].shape[0] == 8


def test_batch_edges_are_real_edges(tiny_dataset, tiny_graph):
    b = tiny_dataset.sample_batch(0, 0, {"ui": 16})
    nu = tiny_graph.n_users
    pairs = set(zip(tiny_graph.ui.src.tolist(), tiny_graph.ui.dst.tolist()))
    for s, d in zip(b["edges"]["ui"]["src_ids"], b["edges"]["ui"]["dst_ids"]):
        assert (int(s), int(d) - nu) in pairs


def test_prefetcher_yields_in_order(tiny_dataset):
    from repro.data.edge_dataset import Prefetcher
    it = tiny_dataset.iter_batches(0, {"ui": 4})
    pf = Prefetcher(it, depth=2)
    got = [next(pf) for _ in range(3)]
    want = [tiny_dataset.sample_batch(t, 0, {"ui": 4}) for t in range(3)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g["edges"]["ui"]["src_ids"],
                                      w["edges"]["ui"]["src_ids"])
    pf.close()


def test_group2_fallback_uses_prev_embeddings(tiny_graph):
    from repro.data.edge_dataset import build_neighbor_tables
    rng = np.random.default_rng(0)
    n = tiny_graph.n_users + tiny_graph.n_items
    prev = rng.normal(size=(n, 8)).astype(np.float32)
    t = build_neighbor_tables(tiny_graph, k_imp=5, n_walks=8, walk_len=3,
                              prev_emb=prev)
    g2 = np.flatnonzero(~tiny_graph.group1_users)
    if len(g2):
        # fallback rows should now be (mostly) filled
        assert (t.user_nbrs[g2] >= 0).mean() > 0.5


# ---------------------------------------------------------------------------
# wigner properties (hypothesis over random rotations / l_max)
# ---------------------------------------------------------------------------

def _rand_rot(rng, n=4):
    A = rng.normal(size=(n, 3, 3))
    Q, _ = np.linalg.qr(A)
    Q[:, :, 0] *= np.sign(np.linalg.det(Q))[:, None]
    return jnp.asarray(Q.astype(np.float32))


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_wigner_orthogonality_property(seed, l_max):
    from repro.models.gnn.wigner import sh_rotation_blocks
    rng = np.random.default_rng(seed)
    R = _rand_rot(rng)
    for l, b in enumerate(sh_rotation_blocks(R, l_max)):
        eye = np.eye(2 * l + 1)
        err = np.abs(np.asarray(jnp.einsum("bij,bkj->bik", b, b))
                     - eye).max()
        assert err < 1e-4, (l, err)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=6, deadline=None)
def test_wigner_homomorphism_property(seed):
    from repro.models.gnn.wigner import sh_rotation_blocks
    rng = np.random.default_rng(seed)
    R1, R2 = _rand_rot(rng), _rand_rot(rng)
    b1 = sh_rotation_blocks(R1, 3)
    b2 = sh_rotation_blocks(R2, 3)
    b12 = sh_rotation_blocks(jnp.einsum("bij,bjk->bik", R1, R2), 3)
    for l in range(4):
        err = np.abs(np.asarray(
            jnp.einsum("bij,bjk->bik", b1[l], b2[l]) - b12[l])).max()
        assert err < 1e-3, (l, err)


def test_rotation_to_z_degenerate_cases():
    from repro.models.gnn.wigner import rotation_to_z
    r = jnp.asarray([[0., 0., 1.], [0., 0., -1.], [1., 0., 0.]],
                    jnp.float32)
    R = rotation_to_z(r)
    mapped = jnp.einsum("bij,bj->bi", R, r)
    np.testing.assert_allclose(np.asarray(mapped),
                               [[0, 0, 1]] * 3, atol=1e-5)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=6, deadline=None)
def test_block_apply_preserves_norm(seed):
    from repro.models.gnn.wigner import sh_rotation_blocks, block_apply
    rng = np.random.default_rng(seed)
    R = _rand_rot(rng, 2)
    x = jnp.asarray(rng.normal(size=(2, 16, 3)).astype(np.float32))
    y = block_apply(sh_rotation_blocks(R, 3), x)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=(1, 2)),
                               np.linalg.norm(np.asarray(y), axis=(1, 2)),
                               rtol=1e-4)
