"""Data pipeline + Wigner-rotation property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_fallback import given, settings, st

from repro.data.synthetic import make_world, next_day_ground_truth


# ---------------------------------------------------------------------------
# synthetic world
# ---------------------------------------------------------------------------

def test_world_shapes_and_determinism():
    w1 = make_world(n_users=100, n_items=150, seed=3)
    w2 = make_world(n_users=100, n_items=150, seed=3)
    np.testing.assert_array_equal(w1.day0.item_id, w2.day0.item_id)
    assert w1.user_feat.shape == (100, 64)
    assert w1.day0.n_users == 100
    assert (w1.day1.timestamp > 86400.0 - 1e-6).all()


def test_world_has_popularity_skew():
    w = make_world(n_users=400, n_items=600, seed=0)
    counts = np.bincount(w.day0.item_id, minlength=600)
    top = np.sort(counts)[::-1]
    assert top[:30].sum() > counts.sum() * 0.15     # head concentration


def test_next_day_ground_truth_csr():
    w = make_world(n_users=50, n_items=60, seed=1)
    u, it, starts, ends = next_day_ground_truth(w)
    for uid in (0, 10, 49):
        mine = it[starts[uid]:ends[uid]]
        truth = w.day1.item_id[w.day1.user_id == uid]
        assert sorted(mine.tolist()) == sorted(truth.tolist())


# ---------------------------------------------------------------------------
# edge dataset
# ---------------------------------------------------------------------------

def test_batch_shapes_and_determinism(tiny_dataset, tiny_cfg):
    b1 = tiny_dataset.sample_batch(5, 0, {"uu": 8, "ui": 8, "ii": 8})
    b2 = tiny_dataset.sample_batch(5, 0, {"uu": 8, "ui": 8, "ii": 8})
    for et in ("uu", "ui", "ii"):
        np.testing.assert_array_equal(b1[et]["src_ids"], b2[et]["src_ids"])
        assert b1[et]["src"]["feat"].shape == (8, 64)
        assert b1[et]["src"]["unbr_feat"].shape == (8, tiny_cfg.k_train, 64)
    b3 = tiny_dataset.sample_batch(6, 0, {"uu": 8, "ui": 8, "ii": 8})
    assert not np.array_equal(b1["ui"]["src_ids"], b3["ui"]["src_ids"])


def test_batch_edges_are_real_edges(tiny_dataset, tiny_graph):
    b = tiny_dataset.sample_batch(0, 0, {"ui": 16})
    nu = tiny_graph.n_users
    pairs = set(zip(tiny_graph.ui.src.tolist(), tiny_graph.ui.dst.tolist()))
    for s, d in zip(b["ui"]["src_ids"], b["ui"]["dst_ids"]):
        assert (int(s), int(d) - nu) in pairs


def test_prefetcher_yields_in_order(tiny_dataset):
    from repro.data.edge_dataset import Prefetcher
    it = tiny_dataset.iter_batches(0, {"ui": 4})
    pf = Prefetcher(it, depth=2)
    got = [next(pf) for _ in range(3)]
    want = [tiny_dataset.sample_batch(t, 0, {"ui": 4}) for t in range(3)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g["ui"]["src_ids"],
                                      w["ui"]["src_ids"])
    pf.close()


def test_group2_fallback_uses_prev_embeddings(tiny_graph):
    from repro.data.edge_dataset import build_neighbor_tables
    rng = np.random.default_rng(0)
    n = tiny_graph.n_users + tiny_graph.n_items
    prev = rng.normal(size=(n, 8)).astype(np.float32)
    t = build_neighbor_tables(tiny_graph, k_imp=5, n_walks=8, walk_len=3,
                              prev_emb=prev)
    g2 = np.flatnonzero(~tiny_graph.group1_users)
    if len(g2):
        # fallback rows should now be (mostly) filled
        assert (t.user_nbrs[g2] >= 0).mean() > 0.5


# ---------------------------------------------------------------------------
# wigner properties (hypothesis over random rotations / l_max)
# ---------------------------------------------------------------------------

def _rand_rot(rng, n=4):
    A = rng.normal(size=(n, 3, 3))
    Q, _ = np.linalg.qr(A)
    Q[:, :, 0] *= np.sign(np.linalg.det(Q))[:, None]
    return jnp.asarray(Q.astype(np.float32))


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_wigner_orthogonality_property(seed, l_max):
    from repro.models.gnn.wigner import sh_rotation_blocks
    rng = np.random.default_rng(seed)
    R = _rand_rot(rng)
    for l, b in enumerate(sh_rotation_blocks(R, l_max)):
        eye = np.eye(2 * l + 1)
        err = np.abs(np.asarray(jnp.einsum("bij,bkj->bik", b, b))
                     - eye).max()
        assert err < 1e-4, (l, err)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=6, deadline=None)
def test_wigner_homomorphism_property(seed):
    from repro.models.gnn.wigner import sh_rotation_blocks
    rng = np.random.default_rng(seed)
    R1, R2 = _rand_rot(rng), _rand_rot(rng)
    b1 = sh_rotation_blocks(R1, 3)
    b2 = sh_rotation_blocks(R2, 3)
    b12 = sh_rotation_blocks(jnp.einsum("bij,bjk->bik", R1, R2), 3)
    for l in range(4):
        err = np.abs(np.asarray(
            jnp.einsum("bij,bjk->bik", b1[l], b2[l]) - b12[l])).max()
        assert err < 1e-3, (l, err)


def test_rotation_to_z_degenerate_cases():
    from repro.models.gnn.wigner import rotation_to_z
    r = jnp.asarray([[0., 0., 1.], [0., 0., -1.], [1., 0., 0.]],
                    jnp.float32)
    R = rotation_to_z(r)
    mapped = jnp.einsum("bij,bj->bi", R, r)
    np.testing.assert_allclose(np.asarray(mapped),
                               [[0, 0, 1]] * 3, atol=1e-5)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=6, deadline=None)
def test_block_apply_preserves_norm(seed):
    from repro.models.gnn.wigner import sh_rotation_blocks, block_apply
    rng = np.random.default_rng(seed)
    R = _rand_rot(rng, 2)
    x = jnp.asarray(rng.normal(size=(2, 16, 3)).astype(np.float32))
    y = block_apply(sh_rotation_blocks(R, 3), x)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=(1, 2)),
                               np.linalg.norm(np.asarray(y), axis=(1, 2)),
                               rtol=1e-4)
