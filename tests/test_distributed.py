"""Distribution: logical sharding rules, multi-device correctness via
subprocess (device count is locked at first jax init, so multi-device
CPU tests run in children with XLA_FLAGS set)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P
from repro.distributed import sharding as shd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_child(script: str) -> str:
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd=REPO,
                       timeout=600)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    return r.stdout


def test_logical_to_spec_basic():
    rules = {"batch": ("pod", "data"), "mlp": "model", "embed": None}
    assert shd.logical_to_spec(("batch", None, "mlp"), rules) == \
        P(("pod", "data"), None, "model")
    assert shd.logical_to_spec(("embed",), rules) == P(None)
    # same mesh axis twice -> second occurrence dropped
    assert shd.logical_to_spec(("mlp", "mlp"), rules) == P("model", None)


def test_make_rules_drops_missing_axes():
    mesh = jax.make_mesh((1,), ("data",))
    rules = shd.make_rules(mesh, None)
    assert rules["mlp"] is None              # no 'model' axis on this mesh
    assert rules["batch"] == ("data",)


def test_constrain_is_noop_without_rules():
    x = jax.numpy.ones((4, 4))
    y = shd.NULL_CTX(x, "batch", "mlp")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.slow
def test_moe_shard_map_matches_reference_multidevice():
    out = _run_child(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import LMConfig
        from repro.models.lm import model as LM
        from repro.distributed.sharding import ShardingCtx, make_rules
        cfg = LMConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                       d_ff=64, moe_d_ff=64, vocab_size=50, n_experts=8,
                       n_experts_per_tok=2, dtype="float32",
                       param_dtype="float32", capacity_factor=8.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = ShardingCtx(make_rules(mesh, {"embed": "data"}), mesh)
        params, _ = LM.init_params(jax.random.key(0), cfg)
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.key(1), (4, 8, 32))
        with mesh:
            o1, _ = jax.jit(lambda lp, x:
                            LM._moe_shard_map(lp, cfg, x, ctx))(lp, x)
        o2, _ = LM._moe_scatter(lp, cfg, x, ShardingCtx())
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-4, atol=2e-5)
        print("SHARDMAP_OK")
    """))
    assert "SHARDMAP_OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Same rankgraph2 train step, 1 device vs 4-device mesh — losses
    must agree to estimator noise (shard-local negatives are the one
    deliberately layout-dependent component)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import RankGraph2Config, RQConfig
        from repro.core import trainer as T
        from repro.data.synthetic import make_world
        from repro.core.graph_builder import build_graph
        from repro.data.edge_dataset import build_neighbor_tables, EdgeDataset
        cfg = RankGraph2Config(d_user_feat=64, d_item_feat=64, d_embed=16,
                               n_heads=2, d_hidden=32, k_imp=6, k_train=4,
                               n_negatives=8, n_pool_neg=4,
                               rq=RQConfig(codebook_sizes=(8, 4), hist_len=8),
                               dtype="float32")
        world = make_world(n_users=150, n_items=200, seed=3)
        g = build_graph(world.day0, k_cap=8, hub_cap=8)
        tables = build_neighbor_tables(g, k_imp=6, n_walks=8, walk_len=3)
        ds = EdgeDataset(g, tables, world.user_feat, world.item_feat, 4)
        state, specs, opt = T.init_state(jax.random.key(0), cfg, pool_size=64)
        step = T.make_train_step(cfg, opt)
        batch = jax.tree.map(jnp.asarray,
                             ds.sample_batch(0, 0, {"uu":16,"ui":16,"ii":16}))
        state, m = step(state, batch, jax.random.key(7))
        print("LOSS", float(m["total"]))
    """)
    o1 = _run_child(script % 1)
    o4 = _run_child(script % 4)
    l1 = float(o1.split("LOSS")[1])
    l4 = float(o4.split("LOSS")[1])
    # shard-local in-batch negatives (see core/negatives.py) make the
    # multi-device loss a different — statistically equivalent —
    # estimator; require the same scale, not bitwise equality.
    np.testing.assert_allclose(l1, l4, rtol=0.05)


@pytest.mark.slow
def test_dryrun_mini_cell_compiles():
    """A reduced dry-run inside a child with 512 fake devices — the
    mesh-building + lower + compile path end-to-end."""
    out = _run_child(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.launch.mesh import make_production_mesh
        from repro.launch.steps import build_cell
        for mesh_kind in (False, True):
            mesh = make_production_mesh(multi_pod=mesh_kind)
            cell = build_cell("sasrec", "serve_p99", mesh)
            with mesh:
                c = jax.jit(cell.fn, in_shardings=cell.in_shardings
                            ).lower(*cell.args).compile()
            assert c.cost_analysis() is not None
        print("DRYRUN_OK")
    """))
    assert "DRYRUN_OK" in out
