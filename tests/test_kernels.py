"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.core import l2_normalize


# ---------------------------------------------------------------------------
# rq_assign
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,d,sizes", [
    (64, 32, (16,)), (100, 64, (32, 8)), (256, 128, (500, 50)),
    (33, 16, (7, 5, 3)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rq_assign_sweep(B, d, sizes, dtype):
    from repro.kernels.rq_assign.ops import rq_assign
    from repro.kernels.rq_assign.ref import rq_assign_ref
    key = jax.random.key(B + d)
    ks = jax.random.split(key, len(sizes) + 1)
    x = jax.random.normal(ks[0], (B, d), dtype)
    books = [jax.random.normal(ks[i + 1], (n, d), dtype) * 0.5
             for i, n in enumerate(sizes)]
    ck, rk = rq_assign(x, books, use_kernel=True, block_b=64)
    cr, rr = rq_assign_ref(x, books)
    # codes are discrete: identical unless distance ties (break by value)
    same = (np.asarray(ck) == np.asarray(cr)).mean()
    assert same > 0.99, f"code agreement {same}"
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    mask = (np.asarray(ck) == np.asarray(cr)).all(axis=1)
    np.testing.assert_allclose(np.asarray(rk)[mask], np.asarray(rr)[mask],
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("V,D,B,L", [(50, 16, 8, 3), (300, 64, 16, 8),
                                     (1000, 32, 5, 1)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("weighted", [False, True])
def test_embedding_bag_sweep(V, D, B, L, mode, weighted):
    from repro.kernels.embedding_bag.ops import embedding_bag
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    key = jax.random.key(V + D)
    k1, k2, k3 = jax.random.split(key, 3)
    table = jax.random.normal(k1, (V, D))
    ids = jax.random.randint(k2, (B, L), -1, V)
    w = jax.random.uniform(k3, (B, L)) if weighted else None
    out_k = embedding_bag(table, ids, w, mode, True)
    out_r = embedding_bag_ref(table, ids, w, mode)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=3e-5, atol=3e-5)


def test_embedding_bag_grad_matches_autodiff():
    from repro.kernels.embedding_bag.ops import embedding_bag
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    key = jax.random.key(0)
    table = jax.random.normal(key, (40, 8))
    ids = jax.random.randint(jax.random.key(1), (6, 4), -1, 40)
    w = jax.random.uniform(jax.random.key(2), (6, 4))
    for mode in ("sum", "mean"):
        g1 = jax.grad(lambda t: jnp.sum(
            embedding_bag(t, ids, w, mode, False) ** 2))(table)
        g2 = jax.grad(lambda t: jnp.sum(
            embedding_bag_ref(t, ids, w, mode) ** 2))(table)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=2e-4, atol=1e-5)
        gw1 = jax.grad(lambda ww: jnp.sum(
            embedding_bag(table, ids, ww, mode, False) ** 2))(w)
        gw2 = jax.grad(lambda ww: jnp.sum(
            embedding_bag_ref(table, ids, ww, mode) ** 2))(w)
        np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2),
                                   rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,T,D,causal", [
    (2, 4, 2, 256, 256, 64, True),
    (1, 2, 2, 200, 200, 32, True),       # ragged
    (2, 4, 1, 1, 300, 64, True),         # decode
    (1, 2, 2, 128, 256, 64, False),      # cross
    (1, 8, 8, 96, 96, 128, True),
])
def test_flash_attention_sweep(B, Hq, Hkv, S, T, D, causal):
    from repro.kernels.flash_attention.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    key = jax.random.key(S + T)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, T, D))
    v = jax.random.normal(ks[2], (B, Hkv, T, D))
    o_k = flash_attention(q, k, v, causal=causal)
    o_r = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    key = jax.random.key(9)
    q = jax.random.normal(key, (1, 2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (1, 2, 128, 64), jnp.bfloat16)
    o_k = flash_attention(q, k, v).astype(jnp.float32)
    o_r = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# ppr_walk
# ---------------------------------------------------------------------------

def _random_padded_adj(N, D2, seed):
    rng = np.random.default_rng(seed)
    nbrs = rng.integers(0, N, (N, D2)).astype(np.int64)
    deg = rng.integers(0, D2 + 1, N)              # some dangling rows
    mask = np.arange(D2)[None, :] < deg[:, None]
    nbrs = np.where(mask, nbrs, -1)
    probs = np.where(mask, rng.random((N, D2)), 0.0)
    tot = probs.sum(1, keepdims=True)
    probs = np.where(tot > 0, probs / np.maximum(tot, 1e-12), 0.0)
    return nbrs, np.cumsum(probs, 1).astype(np.float32)


@pytest.mark.parametrize("N,D2,n,W,L", [
    (64, 8, 16, 4, 3), (128, 16, 8, 8, 2), (200, 4, 12, 2, 5),
])
def test_ppr_walk_sweep(N, D2, n, W, L):
    from repro.core.ppr import walk_uniforms
    from repro.kernels.ppr_walk.ops import ppr_walk
    nbrs, cum = _random_padded_adj(N, D2, N + D2)
    starts = np.random.default_rng(n).integers(0, N, n).astype(np.int64)
    u = walk_uniforms(0, starts, W, L)
    vk, ck = ppr_walk(nbrs, cum, starts, u, restart=0.15, use_kernel=True)
    vr, cr = ppr_walk(nbrs, cum, starts, u, restart=0.15, use_kernel=False)
    # walks are integer traces on a shared uniform stream: exact match
    np.testing.assert_array_equal(np.asarray(vk), vr)
    np.testing.assert_array_equal(np.asarray(ck), cr)
    # counts are multiplicities at first occurrence: rows sum to S
    assert (np.asarray(ck).sum(axis=1) == W * L).all()


# ---------------------------------------------------------------------------
# fused contrastive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,d", [(64, 100, 32), (200, 50, 128),
                                   (7, 10, 16)])
def test_fused_contrastive_sweep(B, N, d):
    from repro.kernels.fused_contrastive.fused_contrastive import (
        fused_contrastive)
    from repro.kernels.fused_contrastive.ref import contrastive_ref
    key = jax.random.key(B + N)
    ks = jax.random.split(key, 3)
    src = l2_normalize(jax.random.normal(ks[0], (B, d)))
    dst = l2_normalize(jax.random.normal(ks[1], (B, d)))
    negs = l2_normalize(jax.random.normal(ks[2], (B, N, d)))
    mk, ik = fused_contrastive(src, dst, negs)
    mr, ir = contrastive_ref(src, dst, negs)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ik), np.asarray(ir), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("B,N,d", [(64, 100, 32), (7, 10, 16),
                                   (130, 24, 48)])
def test_fused_contrastive_vjp_matches_autodiff(B, N, d):
    """The custom-VJP (fused backward tile) against jax.grad of the jnp
    reference — src, dst and negs gradients, arbitrary upstream
    cotangents, ragged batch sizes (pad rows must contribute zero)."""
    from repro.kernels.fused_contrastive.ops import contrastive
    from repro.kernels.fused_contrastive.ref import contrastive_ref
    ks = jax.random.split(jax.random.key(B + N + d), 5)
    src = l2_normalize(jax.random.normal(ks[0], (B, d)))
    dst = l2_normalize(jax.random.normal(ks[1], (B, d)))
    negs = l2_normalize(jax.random.normal(ks[2], (B, N, d)))
    wm = jax.random.normal(ks[3], (B,))
    wi = jax.random.normal(ks[4], (B,))

    def total(fn):
        def f(s, t, n):
            m, i = fn(s, t, n)
            return jnp.sum(wm * m + wi * i)
        return f

    vk, gk = jax.value_and_grad(
        total(lambda s, t, n: contrastive(s, t, n, use_kernel=True)),
        argnums=(0, 1, 2))(src, dst, negs)
    vr, gr = jax.value_and_grad(
        total(contrastive_ref), argnums=(0, 1, 2))(src, dst, negs)
    np.testing.assert_allclose(float(vk), float(vr), rtol=1e-5)
    for a, b, name in zip(gk, gr, ("d_src", "d_dst", "d_negs")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5, err_msg=name)


def test_fused_contrastive_vjp_under_jit_and_mean():
    """The trainer's actual pattern: jnp.mean of both losses inside a
    jitted value_and_grad."""
    from repro.kernels.fused_contrastive.ops import contrastive
    from repro.kernels.fused_contrastive.ref import contrastive_ref
    ks = jax.random.split(jax.random.key(3), 3)
    src = l2_normalize(jax.random.normal(ks[0], (48, 24)))
    dst = l2_normalize(jax.random.normal(ks[1], (48, 24)))
    negs = l2_normalize(jax.random.normal(ks[2], (48, 16, 24)))

    @jax.jit
    def gk(s):
        m, i = contrastive(s, dst, negs, use_kernel=True)
        return jnp.mean(m) + jnp.mean(i)

    @jax.jit
    def gr(s):
        m, i = contrastive_ref(s, dst, negs)
        return jnp.mean(m) + jnp.mean(i)

    np.testing.assert_allclose(np.asarray(jax.grad(gk)(src)),
                               np.asarray(jax.grad(gr)(src)),
                               rtol=2e-4, atol=1e-5)
