"""Fault-tolerance: checkpoint/restore, retention, preemption, elastic."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "b": {"c": jnp.arange(5), "d": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree()
    ck.save(7, tree, metadata={"seed": 0, "data_step": 7})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = ck.restore(like)
    assert meta["step"] == 7 and meta["data_step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_restore_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    with pytest.raises(ValueError):
        ck.restore({"only": jnp.zeros(3)})


def test_atomicity_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_train_state_roundtrip(tmp_path, tiny_cfg, tiny_dataset):
    from repro.core import trainer as T
    state, _, opt = T.init_state(jax.random.key(0), tiny_cfg, pool_size=64)
    step = T.make_train_step(tiny_cfg, opt)     # jitted, donated
    for t in range(3):
        batch = jax.tree.map(jnp.asarray, tiny_dataset.sample_batch(
            t, 0, {"uu": 8, "ui": 8, "ii": 8}))
        state, _ = step(state, batch, jax.random.key(t))
    ck = Checkpointer(str(tmp_path))
    ck.save(int(state.step), state, metadata={"data_seed": 0})
    restored, meta = ck.restore(jax.tree.map(
        lambda x: jnp.zeros_like(x), state))
    assert int(restored.step) == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


ELASTIC_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(sys.argv[1])
    mesh = jax.make_mesh((%d, %d), ("data", "model"))
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    if sys.argv[2] == "save":
        sh = NamedSharding(mesh, P("data", "model"))
        tree = jax.tree.map(lambda x: jax.device_put(x, sh), tree)
        ck.save(1, tree)
    else:
        sh = {"w": NamedSharding(mesh, P("data", "model"))}
        restored, _ = ck.restore({"w": jnp.zeros((8, 8))}, shardings=sh)
        assert restored["w"].sharding.mesh.devices.size == %d
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
        print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Write on a 4x2 mesh, restore onto 2x2 — the elastic-rescale path."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (8, 4, 2, 8),
         str(tmp_path), "save"], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (4, 2, 2, 4),
         str(tmp_path), "load"], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout
