"""Fault-tolerance: checkpoint/restore, retention, preemption, elastic."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (CheckpointCorruptError,
                                           Checkpointer)
from repro.faults import (FaultInjector, FaultPlan, FaultSpec,
                          InjectedCrash, corrupt_file)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "b": {"c": jnp.arange(5), "d": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree()
    ck.save(7, tree, metadata={"seed": 0, "data_step": 7})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = ck.restore(like)
    assert meta["step"] == 7 and meta["data_step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_restore_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    with pytest.raises(ValueError):
        ck.restore({"only": jnp.zeros(3)})


def test_atomicity_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_train_state_roundtrip(tmp_path, tiny_cfg, tiny_dataset):
    from repro.core import trainer as T
    state, _, opt = T.init_state(jax.random.key(0), tiny_cfg, pool_size=64)
    step = T.make_train_step(tiny_cfg, opt)     # jitted, donated
    for t in range(3):
        batch = jax.tree.map(jnp.asarray, tiny_dataset.sample_batch(
            t, 0, {"uu": 8, "ui": 8, "ii": 8}))
        state, _ = step(state, batch, jax.random.key(t))
    ck = Checkpointer(str(tmp_path))
    ck.save(int(state.step), state, metadata={"data_seed": 0})
    restored, meta = ck.restore(jax.tree.map(
        lambda x: jnp.zeros_like(x), state))
    assert int(restored.step) == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- crash-safe publication (PR 9) ------------------------------------------


def test_manifest_records_per_leaf_checksums(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _tree())
    m = ck.verify_step(3)                    # passes: fresh write
    n = m["n_leaves"]
    assert len(m["leaf_sha256"]) == n and len(m["leaf_bytes"]) == n
    assert all(len(s) == 64 for s in m["leaf_sha256"])


def test_verify_step_detects_bit_rot(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _tree())
    corrupt_file(os.path.join(str(tmp_path), "step_3", "000000.npy"),
                 (0, 1))
    with pytest.raises(CheckpointCorruptError, match="mismatch"):
        ck.verify_step(3)
    # restore() itself doesn't verify — callers opt in via verify_step
    assert 3 in ck.all_steps()


def test_verify_step_detects_missing_leaf_and_manifest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    os.unlink(os.path.join(str(tmp_path), "step_1", "000001.npy"))
    with pytest.raises(CheckpointCorruptError, match="leaf 1 missing"):
        ck.verify_step(1)
    with pytest.raises(CheckpointCorruptError, match="manifest missing"):
        ck.verify_step(99)


def test_write_leaf_corrupt_fault_is_detectable(tmp_path):
    # the injected corruption lands AFTER the checksum is recorded, so
    # the torn leaf is a verify failure, not a silent bad read
    faults = FaultInjector(FaultPlan(
        0, [FaultSpec("snapshot.write_leaf", "corrupt",
                      occurrences=(0,))]))
    ck = Checkpointer(str(tmp_path), faults=faults)
    ck.save(2, _tree())
    with pytest.raises(CheckpointCorruptError):
        ck.verify_step(2)


def test_crash_mid_publish_is_not_loadable_as_latest(tmp_path):
    """Satellite: a crash before the atomic rename leaves only a .tmp
    partial — never visible via all_steps/latest_step — and reopening
    the store sweeps it."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    faults = FaultInjector(FaultPlan(
        0, [FaultSpec("snapshot.finalize", "crash", occurrences=(0,))]))
    ck2 = Checkpointer(str(tmp_path), faults=faults)
    with pytest.raises(InjectedCrash):
        ck2.save(2, _tree(2))
    # the partial exists but is invisible to every read path
    assert os.path.isdir(tmp_path / "step_2.tmp")
    assert ck.all_steps() == [1]
    assert ck.latest_step() == 1
    ck.restore(jax.tree.map(jnp.zeros_like, _tree()))     # still loads v1
    # restart: a fresh open sweeps the partial
    ck3 = Checkpointer(str(tmp_path))
    assert not os.path.exists(tmp_path / "step_2.tmp")
    assert ck3.all_steps() == [1]


def test_sweep_partials_reports_what_it_removed(tmp_path):
    os.makedirs(tmp_path / "step_9.tmp")
    (tmp_path / "latest.tmp").write_text("9")
    ck = Checkpointer(str(tmp_path))
    assert not os.path.exists(tmp_path / "step_9.tmp")
    assert not os.path.exists(tmp_path / "latest.tmp")
    assert ck.sweep_partials() == []          # already clean


ELASTIC_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(sys.argv[1])
    mesh = jax.make_mesh((%d, %d), ("data", "model"))
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    if sys.argv[2] == "save":
        sh = NamedSharding(mesh, P("data", "model"))
        tree = jax.tree.map(lambda x: jax.device_put(x, sh), tree)
        ck.save(1, tree)
    else:
        sh = {"w": NamedSharding(mesh, P("data", "model"))}
        restored, _ = ck.restore({"w": jnp.zeros((8, 8))}, shardings=sh)
        assert restored["w"].sharding.mesh.devices.size == %d
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
        print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Write on a 4x2 mesh, restore onto 2x2 — the elastic-rescale path."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (8, 4, 2, 8),
         str(tmp_path), "save"], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % (4, 2, 2, 4),
         str(tmp_path), "load"], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout
