"""Graph-construction unit + property tests (paper §4.2)."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import graph_builder as GB


def _log(n_ev=400, nu=40, ni=60, seed=0):
    rng = np.random.default_rng(seed)
    return GB.EngagementLog(
        user_id=rng.integers(0, nu, n_ev),
        item_id=rng.integers(0, ni, n_ev),
        event_type=rng.integers(0, 4, n_ev).astype(np.int32),
        timestamp=rng.random(n_ev) * 86400, n_users=nu, n_items=ni)


def test_ui_edges_aggregate_events():
    log = _log()
    ui = GB.build_ui_edges(log)
    assert len(ui) > 0
    # weights = sum of event weights per (u, i)
    w = np.array([GB.DEFAULT_EVENT_WEIGHTS[int(t)] for t in log.event_type])
    key = log.user_id * log.n_items + log.item_id
    expect = {}
    for k, ww in zip(key, w):
        expect[k] = expect.get(k, 0.0) + ww
    got = {int(s) * log.n_items + int(d): float(wt)
           for s, d, wt in zip(ui.src, ui.dst, ui.weight)}
    assert set(got) == set(int(k) for k in expect)
    for k in got:
        np.testing.assert_allclose(got[k], expect[k], rtol=1e-5)


def test_unknown_event_types_contribute_zero_weight():
    """Out-of-range event types must not alias onto the boundary weight
    buckets (a corrupt type id used to count as a max-weight buy)."""
    nu, ni = 4, 5
    log = GB.EngagementLog(
        user_id=np.array([0, 1, 2, 3]),
        item_id=np.array([1, 2, 3, 4]),
        event_type=np.array([0, 99, -3, 3], np.int32),   # click/??/??/buy
        timestamp=np.zeros(4), n_users=nu, n_items=ni)
    ui = GB.build_ui_edges(log)
    got = {(int(s), int(d)): float(w)
           for s, d, w in zip(ui.src, ui.dst, ui.weight)}
    # unknown (99) and negative (-3) events create no edges at all
    assert got == {(0, 1): 1.0, (3, 4): 5.0}


def test_unknown_event_types_do_not_inflate_known_pairs():
    nu, ni = 2, 2
    log = GB.EngagementLog(
        user_id=np.array([0, 0, 0]),
        item_id=np.array([1, 1, 1]),
        event_type=np.array([1, 7, -1], np.int32),
        timestamp=np.zeros(3), n_users=nu, n_items=ni)
    ui = GB.build_ui_edges(log)
    assert len(ui) == 1 and float(ui.weight[0]) == 2.0   # like only


def test_hub_subsample_single_anchor_cannot_fake_min_common():
    """One popular anchor must never satisfy cnt >= 2 on its own: a
    with-replacement hub subsample used to emit the same (src, dst)
    pair twice through duplicate offset draws."""
    n_users, n_items = 12, 1
    for seed in range(20):
        # one item engaged once by each of 12 users -> every user pair
        # shares exactly ONE common anchor -> no U-U edge is correct
        log = GB.EngagementLog(
            user_id=np.arange(n_users),
            item_id=np.zeros(n_users, np.int64),
            event_type=np.zeros(n_users, np.int32),
            timestamp=np.zeros(n_users), n_users=n_users, n_items=n_items)
        ui = GB.build_ui_edges(log)
        uu = GB.build_uu_edges(ui, n_users, min_common=2, hub_cap=6,
                               seed=seed)
        assert len(uu) == 0, f"seed {seed}: single-anchor pair passed " \
                             f"min_common"


def test_co_engagement_symmetry_and_threshold():
    log = _log()
    ui = GB.build_ui_edges(log)
    uu = GB.build_uu_edges(ui, log.n_users, min_common=2, hub_cap=64)
    # undirected: both directions present with equal weight
    fwd = {(int(s), int(d)): float(w)
           for s, d, w in zip(uu.src, uu.dst, uu.weight)}
    for (s, d), w in fwd.items():
        assert (d, s) in fwd
        np.testing.assert_allclose(fwd[(d, s)], w, rtol=1e-6)
        assert s != d


def test_co_engagement_matches_bruteforce():
    """With hub_cap >= max item degree the pair weights follow Eq. 1."""
    log = _log(n_ev=200, nu=15, ni=20, seed=3)
    ui = GB.build_ui_edges(log)
    uu = GB.build_uu_edges(ui, log.n_users, min_common=2, hub_cap=1000)
    # brute force
    by_item = {}
    for s, d, w in zip(ui.src, ui.dst, ui.weight):
        by_item.setdefault(int(d), []).append((int(s), float(w)))
    pair_w, pair_c = {}, {}
    for users in by_item.values():
        for a in range(len(users)):
            for b in range(a + 1, len(users)):
                u1, w1 = users[a]
                u2, w2 = users[b]
                kk = (min(u1, u2), max(u1, u2))
                pair_w[kk] = pair_w.get(kk, 0.0) + w1 * w2
                pair_c[kk] = pair_c.get(kk, 0) + 1
    expect = {k: max(np.log(v), 1e-3) for k, v in pair_w.items()
              if pair_c[k] >= 2}
    got = {(int(s), int(d)): float(w)
           for s, d, w in zip(uu.src, uu.dst, uu.weight) if s < d}
    assert set(got) == set(expect)
    for k in got:
        np.testing.assert_allclose(got[k], expect[k], rtol=1e-4)


def test_popularity_bias_correction_downweights_hubs():
    # star: node 0 is popular (edges to 1..9); pair (1,2) is niche
    n = 10
    src = np.array([0] * 9 + list(range(1, 10)) + [1, 2])
    dst = np.array(list(range(1, 10)) + [0] * 9 + [2, 1])
    w = np.ones(len(src), np.float32)
    e = GB.popularity_bias_correction(GB.EdgeSet(src, dst, w), n, alpha=0.3)
    # edge into hub 0 should be strongly downweighted vs edge into leaf 2
    into_hub = e.weight[(e.dst == 0) & (e.src == 1)][0]
    into_leaf = e.weight[(e.dst == 2) & (e.src == 1)][0]
    assert into_hub < into_leaf
    # asymmetry: (1->0) != (0->1) after correction
    rev = e.weight[(e.src == 0) & (e.dst == 1)][0]
    assert abs(into_hub - rev) > 1e-6


@given(st.integers(1, 8), st.integers(5, 60))
@settings(max_examples=20, deadline=None)
def test_topk_per_node_property(k_cap, n_edges):
    rng = np.random.default_rng(n_edges)
    e = GB.EdgeSet(rng.integers(0, 5, n_edges),
                   rng.integers(0, 9, n_edges),
                   rng.random(n_edges).astype(np.float32))
    out = GB.topk_per_node(e, 5, k_cap)
    # per node: at most k_cap edges, and they are the max-weight ones
    for node in range(5):
        kept = np.sort(out.weight[out.src == node])[::-1]
        alln = np.sort(e.weight[e.src == node])[::-1]
        assert len(kept) == min(k_cap, len(alln))
        np.testing.assert_allclose(kept, alln[: len(kept)], rtol=1e-6)


def test_full_build_and_groups(tiny_graph):
    g = tiny_graph
    assert g.n_edges > 0
    # every uu-src is marked group1
    assert g.group1_users[g.uu.src].all()
    assert g.group1_items[g.ii.src].all()
    # subsampling respected
    for es, n in ((g.ui, g.n_users), (g.uu, g.n_users), (g.ii, g.n_items)):
        if len(es):
            counts = np.bincount(es.src, minlength=n)
            assert counts.max() <= 16


def test_retain_users_by_value():
    log = _log()
    ui = GB.build_ui_edges(log)
    mask = GB.retain_users_by_value(ui, log.n_users, budget=10)
    assert mask.sum() == 10
    val = np.zeros(log.n_users)
    np.add.at(val, ui.src, ui.weight)
    assert val[mask].min() >= np.sort(val)[-10 - 1] - 1e-6


def test_padded_adjacency_topweight_order():
    e = GB.EdgeSet(np.array([0, 0, 0, 1]), np.array([1, 2, 3, 0]),
                   np.array([1.0, 3.0, 2.0, 5.0], np.float32))
    nbrs, wts = GB.padded_adjacency(e, 2, 2)
    assert list(nbrs[0]) == [2, 3]        # by weight desc
    assert list(nbrs[1]) == [0, -1]
    assert wts[1, 1] == 0.0
