"""Accelerated construction + hour-level incremental refresh (paper §4.2).

Covers the PPR walker backends (numpy / jax / pallas bit-agreement on
the shared uniform stream), the pad-stall fix, the vectorized top-k
counting, and the incremental-refresh-vs-full-rebuild equivalence.
"""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import graph_builder as GB
from repro.core import ppr as P
from repro.data.edge_dataset import (build_neighbor_tables,
                                     incremental_refresh)
from repro.data.synthetic import make_world


def _small_graph(nu=50, ni=70, seed=3, **kw):
    world = make_world(n_users=nu, n_items=ni, events_per_user=10.0,
                       seed=seed)
    kw.setdefault("k_cap", 8)
    kw.setdefault("hub_cap", 64)
    return GB.build_graph(world.day0, **kw)


# ---------------------------------------------------------------------------
# walker backends
# ---------------------------------------------------------------------------

def test_walk_uniforms_keyed_by_node_id():
    full = P.walk_uniforms(7, np.arange(2 * P.U_BLOCK // 64), 4, 3)
    for i in (0, 5, 100):
        one = P.walk_uniforms(7, np.array([i]), 4, 3)
        np.testing.assert_array_equal(one[0], full[i])


def test_precompute_backends_bit_identical():
    g = _small_graph()
    kw = dict(k_imp=6, n_walks=8, walk_len=3, seed=0)
    un, itn = P.precompute_ppr_neighbors(g, backend="numpy", **kw)
    uj, itj = P.precompute_ppr_neighbors(g, backend="jax", **kw)
    up, itp = P.precompute_ppr_neighbors(g, backend="pallas", **kw)
    np.testing.assert_array_equal(un, uj)
    np.testing.assert_array_equal(itn, itj)
    np.testing.assert_array_equal(un, up)
    np.testing.assert_array_equal(itn, itp)


def test_backends_bit_identical_non_power_of_two_degree():
    """D2 = 2*max_deg_per_type is not a power of two for odd caps; the
    jax binary search must still find sum(cum < u) exactly."""
    g = _small_graph(nu=40, ni=60, seed=7)
    for mdeg in (7, 5):                          # D2 = 14, 10
        adj = P.build_padded_hetero_adj(g, mdeg)
        starts = np.arange(adj.n_nodes, dtype=np.int64)
        kw = dict(n_walks=8, walk_len=3, seed=1)
        vn, _ = P.ppr_visit_counts(adj, starts, backend="numpy", **kw)
        vj, _ = P.ppr_visit_counts(adj, starts, backend="jax", **kw)
        np.testing.assert_array_equal(vn, vj)


def test_unknown_backend_raises():
    g = _small_graph(nu=10, ni=12)
    with pytest.raises(ValueError, match="backend"):
        adj = P.build_padded_hetero_adj(g, 4)
        P.ppr_visit_counts(adj, np.arange(4), backend="torch")


# ---------------------------------------------------------------------------
# pad-stall fix: an overflowing f32 draw must not strand the walker on a
# trailing -1 pad
# ---------------------------------------------------------------------------

def _stall_adj():
    """Row 0's cumulative mass tops out below 1.0 and its second column
    is a pad: a draw above cum[-1] used to stall the walker at node 0."""
    nbrs = np.array([[1, -1], [0, -1]], np.int64)
    c = np.float32(0.9999999)
    cum = np.array([[c, c], [1.0, 1.0]], np.float32)
    return nbrs, cum


def test_pad_stall_numpy_step():
    nbrs, cum = _stall_adj()
    last = P.last_valid_cols(cum)
    u = np.array([np.float32(0.99999997)])        # > cum[-1]
    nxt = P._step(nbrs, cum, last, np.array([0]), u)
    assert nxt[0] == 1                            # moved, not stalled


def test_pad_stall_all_backends_agree():
    nbrs, cum = _stall_adj()
    starts = np.array([0], np.int64)
    # one walk, one step: step draw overflows, no restart
    uniforms = np.array([[[0.99999997, 0.9]]], np.float32)
    vis_j = P.ppr_walk_jax(nbrs, cum, starts, uniforms, n_walks=1,
                           walk_len=1, restart=0.15)
    from repro.kernels.ppr_walk.ops import ppr_walk
    vis_k, cnt_k = ppr_walk(nbrs, cum, starts, uniforms, restart=0.15,
                            use_kernel=True)
    vis_r, cnt_r = ppr_walk(nbrs, cum, starts, uniforms, restart=0.15,
                            use_kernel=False)
    assert vis_j[0, 0] == 1
    assert np.asarray(vis_k)[0, 0] == 1 and vis_r[0, 0] == 1
    np.testing.assert_array_equal(np.asarray(cnt_k), cnt_r)


def test_dangling_rows_still_stay_put():
    nbrs = np.array([[-1, -1], [0, -1]], np.int64)
    cum = np.array([[0.0, 0.0], [1.0, 1.0]], np.float32)
    last = P.last_valid_cols(cum)
    nxt = P._step(nbrs, cum, last, np.array([0]), np.array([0.5]))
    assert nxt[0] == 0


# ---------------------------------------------------------------------------
# vectorized visit counting / top-k
# ---------------------------------------------------------------------------

def _brute_topk(visited, starts, k, boundary):
    n, S = visited.shape
    users = np.full((n, k), -1, np.int64)
    items = np.full((n, k), -1, np.int64)
    for r in range(n):
        cnt = {}
        for v in visited[r]:
            if v != starts[r]:
                cnt[int(v)] = cnt.get(int(v), 0) + 1
        for side, out in ((0, users), (1, items)):
            cand = [(c, v) for v, c in cnt.items()
                    if (v >= boundary) == bool(side)]
            cand.sort(key=lambda cv: (-cv[0], cv[1]))
            for j, (c, v) in enumerate(cand[:k]):
                out[r, j] = v
    return users, items


@given(st.integers(1, 6), st.integers(2, 30), st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_topk_by_count_matches_bruteforce(k, S, seed):
    rng = np.random.default_rng(seed)
    n, boundary = 5, 8
    visited = rng.integers(0, 16, (n, S))
    starts = rng.integers(0, 16, n)
    u, it = P.topk_by_count(visited, starts, k, boundary, boundary)
    ub, ib = _brute_topk(visited, starts, k, boundary)
    np.testing.assert_array_equal(u, ub)
    np.testing.assert_array_equal(it, ib)


def test_run_length_counts_vectorized():
    srt = np.sort(np.array([[3, 3, 1, 7, 3, 7, 9, 9, 9, 1]]), axis=1)
    counts = P._run_length_counts(srt)
    got = {int(v): int(c) for v, c in zip(srt[0], counts[0]) if c > 0}
    assert got == {1: 2, 3: 3, 7: 2, 9: 3}
    assert counts.sum() == srt.shape[1]


def test_fused_kernel_counts_match_host_counting():
    g = _small_graph(nu=30, ni=40)
    adj = P.build_padded_hetero_adj(g, 8)
    starts = np.arange(12, dtype=np.int64)
    u = P.walk_uniforms(0, starts, 6, 3)
    from repro.kernels.ppr_walk.ops import ppr_walk
    vis, cnt = ppr_walk(adj.nbrs, adj.cum, starts, u, restart=0.15)
    vis, cnt = np.asarray(vis, np.int64), np.asarray(cnt, np.int64)
    # kernel counts (visit order) and host run-length counts (sorted
    # order) must select identical top-k neighbors
    glob = P.global_visit_mass(vis, adj.n_nodes)
    uk, ik = P._topk_from_counts(vis, cnt, starts, 5, g.n_users, 0.5,
                                 glob)
    uh, ih = P.topk_by_count(vis, starts, 5, g.n_users, g.n_users,
                             hub_alpha=0.5, glob=glob)
    np.testing.assert_array_equal(uk, uh)
    np.testing.assert_array_equal(ik, ih)


# ---------------------------------------------------------------------------
# pipeline regression: steps=0 must not crash
# ---------------------------------------------------------------------------

def test_run_pipeline_zero_steps(tiny_world, tiny_cfg):
    from repro.core.pipeline import run_pipeline
    res = run_pipeline(tiny_world, tiny_cfg, steps=0, batch_per_type=16)
    assert res.metrics == {}
    assert res.user_emb.shape[0] == tiny_world.n_users


# ---------------------------------------------------------------------------
# incremental refresh vs full rebuild
# ---------------------------------------------------------------------------

def _split_log(log, t_cut):
    m = log.timestamp <= t_cut
    old = GB.EngagementLog(log.user_id[m], log.item_id[m],
                           log.event_type[m], log.timestamp[m],
                           log.n_users, log.n_items)
    delta = log.window(86400.0, 86400.0 - t_cut)
    return old, delta


def test_incremental_refresh_equals_full_rebuild():
    world = make_world(n_users=60, n_items=80, events_per_user=8.0,
                       seed=5)
    old, delta = _split_log(world.day0, 79200.0)        # 22h | 2h delta
    assert len(delta.user_id) > 0
    kw = dict(k_cap=12, hub_cap=512)                    # no hub RNG
    pw = dict(k_imp=6, n_walks=8, walk_len=3, seed=0)
    g_old = GB.build_graph(old, keep_state=True, **kw)
    t_old = build_neighbor_tables(g_old, keep_state=True, **pw)
    g_ref, t_ref, rep = incremental_refresh(g_old, t_old, delta)
    g_full = GB.build_graph(world.day0, **kw)
    t_full = build_neighbor_tables(g_full, **pw)

    # edge sets match a full rebuild bitwise, everywhere
    for et in ("ui", "uu", "ii"):
        a, b = getattr(g_ref, et), getattr(g_full, et)
        np.testing.assert_array_equal(a.src, b.src)
        np.testing.assert_array_equal(a.dst, b.dst)
        np.testing.assert_array_equal(a.weight, b.weight)
    np.testing.assert_array_equal(g_ref.group1_users, g_full.group1_users)
    np.testing.assert_array_equal(g_ref.group1_items, g_full.group1_items)

    # affected table rows match the full rebuild; unaffected rows stable
    n = g_full.n_users + g_full.n_items
    am = np.zeros(n, bool)
    am[rep["affected_nodes"]] = True
    np.testing.assert_array_equal(t_ref.user_nbrs[am], t_full.user_nbrs[am])
    np.testing.assert_array_equal(t_ref.item_nbrs[am], t_full.item_nbrs[am])
    np.testing.assert_array_equal(t_ref.user_nbrs[~am], t_old.user_nbrs[~am])
    np.testing.assert_array_equal(t_ref.item_nbrs[~am], t_old.item_nbrs[~am])


def test_incremental_refresh_fractional_event_weights():
    """U-I aggregates stay float64 through the merge: fractional event
    weights must not double-round versus a from-scratch build."""
    world = make_world(n_users=40, n_items=50, events_per_user=8.0,
                       seed=13)
    old, delta = _split_log(world.day0, 79200.0)
    ew = {0: 0.1, 1: 0.3, 2: 0.7, 3: 1.3}
    kw = dict(k_cap=8, hub_cap=512, event_weights=ew)
    pw = dict(k_imp=5, n_walks=8, walk_len=2, seed=0)
    g_old = GB.build_graph(old, keep_state=True, **kw)
    t_old = build_neighbor_tables(g_old, keep_state=True, **pw)
    g_ref, t_ref, rep = incremental_refresh(g_old, t_old, delta)
    g_full = GB.build_graph(world.day0, **kw)
    t_full = build_neighbor_tables(g_full, **pw)
    for et in ("ui", "uu", "ii"):
        a, b = getattr(g_ref, et), getattr(g_full, et)
        np.testing.assert_array_equal(a.src, b.src)
        np.testing.assert_array_equal(a.weight, b.weight)
    am = np.zeros(g_full.n_users + g_full.n_items, bool)
    am[rep["affected_nodes"]] = True
    np.testing.assert_array_equal(t_ref.user_nbrs[am], t_full.user_nbrs[am])
    np.testing.assert_array_equal(t_ref.item_nbrs[am], t_full.item_nbrs[am])


def test_incremental_refresh_grows_item_space_and_routes_group2():
    world = make_world(n_users=50, n_items=60, events_per_user=8.0,
                       seed=2)
    old = world.day0
    ni_new = 65
    rng = np.random.default_rng(9)
    du = rng.integers(0, 50, 30).astype(np.int64)
    di = np.r_[rng.integers(0, 60, 25), np.arange(60, 65)].astype(np.int64)
    delta = GB.EngagementLog(du, di,
                             rng.integers(0, 4, 30).astype(np.int32),
                             np.full(30, 90000.0), 50, ni_new)
    merged = GB.EngagementLog(
        np.r_[old.user_id, delta.user_id],
        np.r_[old.item_id, delta.item_id],
        np.r_[old.event_type, delta.event_type],
        np.r_[old.timestamp, delta.timestamp], 50, ni_new)
    kw = dict(k_cap=12, hub_cap=512)
    pw = dict(k_imp=6, n_walks=8, walk_len=3, seed=0)
    prev_emb = rng.normal(0, 1, (50 + ni_new, 16)).astype(np.float32)
    g_old = GB.build_graph(old, keep_state=True, **kw)
    t_old = build_neighbor_tables(g_old, keep_state=True, **pw)
    g_ref, t_ref, rep = incremental_refresh(g_old, t_old, delta,
                                            prev_emb=prev_emb)
    g_full = GB.build_graph(merged, **kw)
    t_full = build_neighbor_tables(g_full, **pw, prev_emb=prev_emb)

    assert g_ref.n_items == ni_new
    assert t_ref.user_nbrs.shape[0] == 50 + ni_new
    n = 50 + ni_new
    am = np.zeros(n, bool)
    am[rep["affected_nodes"]] = True
    assert am[50 + np.arange(60, 65)].all()      # new items are affected
    np.testing.assert_array_equal(t_ref.user_nbrs[am], t_full.user_nbrs[am])
    np.testing.assert_array_equal(t_ref.item_nbrs[am], t_full.item_nbrs[am])
    # fresh items without same-type co-engagement route through the
    # Group-2 KNN fallback: same-type neighbor rows are populated
    fresh_g2 = [gid for gid in 50 + np.arange(60, 65)
                if not g_ref.group1_items[gid - 50]]
    assert fresh_g2
    g1i = np.flatnonzero(g_ref.group1_items)
    for gid in fresh_g2:
        row = t_ref.item_nbrs[gid]
        assert (row >= 0).any()
        assert (row[row >= 0] >= 50).all()       # same-type = items
        knn = P.group2_neighbors(prev_emb[50:], g1i,
                                 np.array([gid - 50]), 6)[0]
        m = knn >= 0
        np.testing.assert_array_equal(row[m], 50 + knn[m])


def test_incremental_refresh_bitwise_under_hub_subsampling():
    """hub_cap small enough to trigger: keyed, persisted hub draws must
    keep refresh == full rebuild bitwise (the old per-call RNG stream
    diverged here)."""
    world = make_world(n_users=50, n_items=40, events_per_user=20.0,
                       seed=11)
    old, delta = _split_log(world.day0, 79200.0)
    assert len(delta.user_id) > 0
    kw = dict(k_cap=12, hub_cap=6)                      # hubs everywhere
    pw = dict(k_imp=6, n_walks=8, walk_len=3, seed=0)
    g_old = GB.build_graph(old, keep_state=True, **kw)
    st = g_old.refresh
    assert (len(st.hub_draws["uu"].anchor_ids) > 0
            or len(st.hub_draws["ii"].anchor_ids) > 0)  # cap triggered
    t_old = build_neighbor_tables(g_old, keep_state=True, **pw)
    g_ref, t_ref, rep = incremental_refresh(g_old, t_old, delta)
    g_full = GB.build_graph(world.day0, **kw)
    t_full = build_neighbor_tables(g_full, **pw)
    for et in ("ui", "uu", "ii"):
        a, b = getattr(g_ref, et), getattr(g_full, et)
        np.testing.assert_array_equal(a.src, b.src)
        np.testing.assert_array_equal(a.dst, b.dst)
        np.testing.assert_array_equal(a.weight, b.weight)
    np.testing.assert_array_equal(t_ref.user_nbrs, t_full.user_nbrs)
    np.testing.assert_array_equal(t_ref.item_nbrs, t_full.item_nbrs)


def test_hub_draws_persisted_and_reused():
    """Sanity on the persisted offsets: a refresh with an empty-ish delta
    keeps untouched anchors' draws verbatim, and redrawn offsets are a
    pure function of (seed, tag, anchor id, degree)."""
    world = make_world(n_users=40, n_items=30, events_per_user=20.0,
                       seed=3)
    g = GB.build_graph(world.day0, k_cap=12, hub_cap=6, keep_state=True)
    d0 = g.refresh.hub_draws
    assert len(d0["uu"].anchor_ids) or len(d0["ii"].anchor_ids)
    # keyed regeneration reproduces the persisted offsets exactly
    for tag in ("uu", "ii"):
        hd = d0[tag]
        if not len(hd.anchor_ids):
            continue
        u = GB.hub_uniforms(0, tag, hd.anchor_ids, hd.offsets.shape[1])
        o = (u * hd.lens[:, None]).astype(np.int64)
        o.sort(axis=1)
        dup = np.zeros_like(o, bool)
        dup[:, 1:] = o[:, 1:] == o[:, :-1]
        o[dup] = -1
        np.testing.assert_array_equal(o, hd.offsets)


def test_incremental_refresh_grows_user_space():
    """User growth: the unified id space shifts (items move up by the
    number of new users); refreshed tables must match a full rebuild on
    affected rows and equal the remapped old tables elsewhere."""
    nu, ni = 50, 60
    world = make_world(n_users=nu, n_items=ni, events_per_user=8.0,
                       seed=21)
    old = world.day0
    nu_new = 56
    rng = np.random.default_rng(17)
    # delta: some old users re-engage + 6 brand-new users engage
    du = np.r_[rng.integers(0, nu, 20),
               np.arange(nu, nu_new)].astype(np.int64)
    di = rng.integers(0, ni, len(du)).astype(np.int64)
    delta = GB.EngagementLog(du, di,
                             rng.integers(0, 4, len(du)).astype(np.int32),
                             np.full(len(du), 90000.0), nu_new, ni)
    merged = GB.EngagementLog(
        np.r_[old.user_id, delta.user_id],
        np.r_[old.item_id, delta.item_id],
        np.r_[old.event_type, delta.event_type],
        np.r_[old.timestamp, delta.timestamp], nu_new, ni)
    kw = dict(k_cap=12, hub_cap=512)
    pw = dict(k_imp=6, n_walks=8, walk_len=3, seed=0)
    prev_emb = rng.normal(0, 1, (nu_new + ni, 16)).astype(np.float32)
    g_old = GB.build_graph(old, keep_state=True, **kw)
    t_old = build_neighbor_tables(g_old, keep_state=True, **pw)
    g_ref, t_ref, rep = incremental_refresh(g_old, t_old, delta,
                                            prev_emb=prev_emb)
    g_full = GB.build_graph(merged, **kw)
    t_full = build_neighbor_tables(g_full, **pw, prev_emb=prev_emb)

    assert g_ref.n_users == nu_new
    n = nu_new + ni
    assert t_ref.user_nbrs.shape[0] == n
    am = np.zeros(n, bool)
    am[rep["affected_nodes"]] = True
    assert am[np.arange(nu, nu_new)].all()       # new users are affected
    # edge sets match the full rebuild bitwise
    for et in ("ui", "uu", "ii"):
        a, b = getattr(g_ref, et), getattr(g_full, et)
        np.testing.assert_array_equal(a.src, b.src)
        np.testing.assert_array_equal(a.dst, b.dst)
        np.testing.assert_array_equal(a.weight, b.weight)
    # affected rows match the rebuild; unaffected rows == remapped old
    np.testing.assert_array_equal(t_ref.user_nbrs[am], t_full.user_nbrs[am])
    np.testing.assert_array_equal(t_ref.item_nbrs[am], t_full.item_nbrs[am])
    shift = nu_new - nu
    old_pos = np.r_[np.arange(nu), np.arange(nu, nu + ni) + shift]
    remap = lambda a: np.where(a >= nu, a + shift, a)   # noqa: E731
    for t_r, t_o in ((t_ref.user_nbrs, t_old.user_nbrs),
                     (t_ref.item_nbrs, t_old.item_nbrs)):
        carried = ~am[old_pos]
        np.testing.assert_array_equal(t_r[old_pos[carried]],
                                      remap(t_o[carried]))


def test_refresh_leaves_isolated_component_untouched():
    """A disconnected community never reachable from the delta keeps its
    tables bit-identical (and is not re-walked at all)."""
    nu, ni = 20, 20
    rng = np.random.default_rng(0)
    # two disjoint communities: users/items [0, 10) and [10, 20)
    ev_u, ev_i = [], []
    for base in (0, 10):
        u = rng.integers(base, base + 10, 120)
        i = rng.integers(base, base + 10, 120)
        ev_u.append(u)
        ev_i.append(i)
    log = GB.EngagementLog(
        np.concatenate(ev_u), np.concatenate(ev_i),
        rng.integers(0, 4, 240).astype(np.int32),
        rng.random(240) * 80000.0, nu, ni)
    delta = GB.EngagementLog(                   # touches community 0 only
        rng.integers(0, 10, 15), rng.integers(0, 10, 15),
        rng.integers(0, 4, 15).astype(np.int32),
        np.full(15, 85000.0), nu, ni)
    kw = dict(k_cap=8, hub_cap=512)
    g_old = GB.build_graph(log, keep_state=True, **kw)
    t_old = build_neighbor_tables(g_old, k_imp=5, n_walks=8, walk_len=3,
                                  keep_state=True)
    g_ref, t_ref, rep = incremental_refresh(g_old, t_old, delta)
    iso = np.r_[np.arange(10, 20), nu + np.arange(10, 20)]
    assert not np.isin(iso, rep["affected_nodes"]).any()
    np.testing.assert_array_equal(t_ref.user_nbrs[iso], t_old.user_nbrs[iso])
    np.testing.assert_array_equal(t_ref.item_nbrs[iso], t_old.item_nbrs[iso])


def test_refresh_requires_state():
    g = _small_graph(nu=10, ni=12, keep_state=False)
    assert g.refresh is None
    delta = GB.EngagementLog(np.array([0]), np.array([0]),
                             np.array([0], np.int32), np.array([0.0]),
                             10, 12)
    with pytest.raises(ValueError, match="keep_state"):
        GB.refresh_graph(g, delta)


def test_refresh_rejects_shrinking_id_spaces():
    g = _small_graph(nu=10, ni=12, keep_state=True)
    delta = GB.EngagementLog(np.array([0]), np.array([0]),
                             np.array([0], np.int32), np.array([0.0]),
                             9, 12)
    with pytest.raises(ValueError, match="user space"):
        GB.refresh_graph(g, delta)
    delta = GB.EngagementLog(np.array([0]), np.array([0]),
                             np.array([0], np.int32), np.array([0.0]),
                             10, 11)
    with pytest.raises(ValueError, match="item space"):
        GB.refresh_graph(g, delta)
