"""Concurrent serving tier: per-thread reader pools, MVCC retrieval
against a live-ingesting device store, the preserved host engine's
seqlock discipline, multi-writer EventRing, and the deterministic
lost-event swap race regression (sharded and unsharded).

The heavyweight R-reader/W-writer storm with throughput gating lives in
``benchmarks/serving_concurrency.py`` (host engine) and
``benchmarks/serving_scaleout.py`` (device engine); these tests pin the
individual contracts at test-tier sizes.
"""
import threading
import time

import numpy as np

from repro.core.serving import (BufPool, ClusterQueueStore,
                                HostQueueStore, ShardedQueueStore,
                                ThreadLocalPools, u2i2i_retrieve_batch)
from repro.lifecycle.swap import EventRing, SwapServer
from repro.lifecycle.snapshot import IndexSnapshot, derive_members
from repro.obs import FixedClock, MemorySink, Telemetry

from tests._hypothesis_fallback import given, settings, st


# ---------------------------------------------------------------------------
# per-thread reader pools
# ---------------------------------------------------------------------------

def test_thread_local_pools_are_per_thread():
    pools = ThreadLocalPools()
    main_pool = pools.get()
    assert pools.get() is main_pool           # stable within a thread
    assert isinstance(main_pool, BufPool)
    got = {}

    def grab(name):
        got[name] = pools.get()

    ths = [threading.Thread(target=grab, args=(i,)) for i in range(3)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    pool_ids = {id(p) for p in got.values()} | {id(main_pool)}
    assert len(pool_ids) == 4                 # no sharing across threads


def test_concurrent_readers_match_single_thread_bitwise():
    """N reader threads over one store: every response identical to the
    single-threaded result (no scratch aliasing between threads)."""
    rng = np.random.default_rng(0)
    n_users, n_items, C = 200, 300, 16
    store = ClusterQueueStore(rng.integers(0, C, n_users), queue_len=32,
                              recency_s=1e9)
    store.ingest(rng.integers(0, n_users, 3000),
                 rng.integers(0, n_items, 3000),
                 rng.integers(0, 1000, 3000).astype(float))
    batches = [rng.integers(0, n_users, 64) for _ in range(8)]
    want = [store.retrieve_batch(u, 1000.0, 16) for u in batches]
    errs = []

    def reader():
        try:
            for _ in range(10):
                for u, w in zip(batches, want):
                    np.testing.assert_array_equal(
                        store.retrieve_batch(u, 1000.0, 16), w)
        except Exception as e:                # surfaced after join
            errs.append(e)

    ths = [threading.Thread(target=reader) for _ in range(4)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    assert not errs, errs


# ---------------------------------------------------------------------------
# seqlock: readers against a concurrently-ingesting store
# ---------------------------------------------------------------------------

def test_retrieve_during_concurrent_ingest_then_oracle():
    """Readers run lock-free while W writers ingest; mid-flight
    responses must be well-formed, and once writers finish the store
    must equal a single-threaded oracle bitwise (zero lost events,
    zero torn writes).

    Writers own disjoint clusters (user id mod W) and emit strictly
    increasing timestamps, so the per-cluster slot order is the
    timestamp order regardless of how the threads interleave — which is
    exactly what makes the oracle comparison bitwise."""
    W, C, n_users, n_items = 2, 8, 64, 100
    clusters = np.arange(n_users) % C          # cluster % W == user % W
    store = ClusterQueueStore(clusters, queue_len=16, recency_s=1e9)
    per_writer = [[] for _ in range(W)]
    errs = []

    def writer(w):
        try:
            rng = np.random.default_rng(100 + w)
            for step in range(60):
                n = int(rng.integers(1, 12))
                u = rng.integers(0, n_users // W, n) * W + w
                it = rng.integers(0, n_items, n)
                ts = (np.arange(n) + step * 32) * W + w
                per_writer[w].append((u, it, ts.astype(float)))
                store.ingest(u, it, ts.astype(float))
        except Exception as e:
            errs.append(e)

    def reader():
        try:
            rng = np.random.default_rng(7)
            for _ in range(80):
                out = store.retrieve_batch(
                    rng.integers(0, n_users, 32), 1e6, 8)
                assert ((out == -1) | ((out >= 0) & (out < n_items))).all()
        except Exception as e:
            errs.append(e)

    ths = ([threading.Thread(target=writer, args=(w,)) for w in range(W)]
           + [threading.Thread(target=reader) for _ in range(2)])
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    assert not errs, errs

    oracle = ClusterQueueStore(clusters, queue_len=16, recency_s=1e9)
    ev = [np.concatenate(x) for x in zip(
        *(e for w in per_writer for e in w))]
    order = np.argsort(ev[2], kind="stable")
    oracle.ingest(ev[0][order], ev[1][order], ev[2][order])
    users = np.arange(n_users)
    np.testing.assert_array_equal(store.retrieve_batch(users, 1e6, 16),
                                  oracle.retrieve_batch(users, 1e6, 16))
    np.testing.assert_array_equal(store.cursor, oracle.cursor)


def test_seqlock_fallback_under_writer_pressure():
    """The bounded-spin fallback path must return a consistent result
    even when a writer holds the write lock across the reader's whole
    spin budget (forced via a tiny spin budget).  Host engine: the
    device store has no seqlock (MVCC)."""
    store = HostQueueStore(np.array([0, 1]), queue_len=8,
                           recency_s=1e9)
    store.ingest(np.array([0, 1]), np.array([5, 6]),
                 np.array([1.0, 2.0]))
    store._SEQLOCK_SPINS = 0  # always take the locked fallback
    assert store.retrieve(0, 10.0, 4) == [5]
    assert store.retrieve(1, 10.0, 4) == [6]


# ---------------------------------------------------------------------------
# EventRing: multi-writer push
# ---------------------------------------------------------------------------

def test_event_ring_multi_writer_exactly_once():
    """W threads push concurrently: after join the committed watermark
    equals the reserved cursor and the trailing window holds every
    event exactly once (atomic reservation, no overwrites)."""
    W, pushes, n = 4, 40, 7
    ring = EventRing(capacity=1 << 12)

    def writer(w):
        for s in range(pushes):
            base = (w * pushes + s) * n
            ids = np.arange(base, base + n)
            ring.push(ids, ids + 1, ids.astype(float))

    ths = [threading.Thread(target=writer, args=(w,)) for w in range(W)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    total = W * pushes * n
    assert ring.cursor == total
    assert ring.committed == total            # no gap left un-closed
    u, i, t, end = ring.window_since(0, -np.inf)
    assert end == total and len(u) == total
    np.testing.assert_array_equal(np.sort(u), np.arange(total))
    np.testing.assert_array_equal(i, u + 1)   # rows never mixed across slots
    np.testing.assert_array_equal(t, u.astype(float))


def test_event_ring_window_clamps_below_inflight_wrap():
    """Wrap safety: with a reservation in flight past the wrap point,
    physical slots below ``cursor - capacity`` may be mid-overwrite —
    ``window_since`` must clamp them out rather than return a possibly
    torn prefix (white-box: the in-flight push is simulated by bumping
    the reserved cursor past the committed watermark)."""
    ring = EventRing(capacity=8)
    ring.push(np.arange(5), np.arange(5) + 100, np.arange(5, dtype=float))
    ring.push(np.arange(5, 10), np.arange(5, 10) + 100,
              np.arange(5, 10, dtype=float))  # committed = cursor = 10
    ring.cursor = 15                          # in-flight: [10, 15)
    u, i, _, end = ring.window_since(0, -np.inf)
    assert end == 10
    # positions [2, 7) alias the in-flight write's slots; only [7, 10)
    # are provably stable
    assert u.tolist() == [7, 8, 9]
    assert i.tolist() == [107, 108, 109]
    ring.cursor = 10                          # quiesced again
    u, _, _, _ = ring.window_since(0, -np.inf)
    assert u.tolist() == [2, 3, 4, 5, 6, 7, 8, 9]


def test_event_ring_wrapped_multi_writer_never_tears():
    """Writers lap a tiny ring — with batch sizes whose combined
    in-flight span exceeds capacity, so reservation backpressure is
    exercised — while a reader chains ``window_since``: delivered
    events may skip overwritten positions, but every delivered tuple
    must be internally consistent (never one push's user with
    another's item/ts) and no position is delivered twice."""
    ring = EventRing(capacity=64)             # laps many times
    W, pushes = 4, 120
    stop = threading.Event()
    errs = []

    def writer(w):
        try:
            rng = np.random.default_rng(w)
            for s in range(pushes):
                n = int(rng.integers(1, 33))  # 4 writers x 32 > capacity
                base = (w * pushes + s) * 40
                ids = np.arange(base, base + n)
                ring.push(ids, ids + 1_000_000, ids.astype(float))
        except Exception as e:                # pragma: no cover
            errs.append(e)

    seen_pos = dict(n=0)

    def reader():
        try:
            seen = 0
            while not stop.is_set() or seen < ring.committed:
                u, i, t, end = ring.window_since(seen, -np.inf)
                assert end >= seen
                np.testing.assert_array_equal(i, u + 1_000_000)
                np.testing.assert_array_equal(t, u.astype(float))
                seen_pos["n"] += len(u)
                seen = end
        except Exception as e:                # pragma: no cover
            errs.append(e)

    ths = [threading.Thread(target=writer, args=(w,)) for w in range(W)]
    rd = threading.Thread(target=reader)
    rd.start()
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    stop.set()
    rd.join()
    assert not errs, errs
    assert ring.committed == ring.cursor
    assert seen_pos["n"] <= ring.cursor       # positions never re-delivered


def test_event_ring_push_reports_dropped():
    ring = EventRing(capacity=8)
    assert ring.push(np.arange(5), np.arange(5), np.arange(5.0)) == 0
    # a batch larger than the whole ring truncates to its tail — and
    # says so
    assert ring.push(np.arange(20), np.arange(20),
                     np.arange(20.0)) == 12
    u, _, _, end = ring.window_since(0, -np.inf)
    assert end == 13 and u.tolist() == list(range(12, 20))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=17),
                min_size=1, max_size=12),
       st.integers(min_value=4, max_value=40))
def test_event_ring_watermark_monotone_and_gap_free(sizes, capacity):
    """Property: chaining ``window_since`` through the returned cursor
    yields a monotone watermark and exactly the retained stream — every
    retained event delivered once, in order, except those that fell off
    the ring's trailing window between reads.  (A push larger than the
    whole ring retains only its tail and reports the rest dropped, so
    ring positions count retained events.)"""
    ring = EventRing(capacity=capacity)
    seen = 0
    eid = 0
    retained: list = []                       # the ring's position stream
    delivered = []
    for n in sizes:
        ids = np.arange(eid, eid + n)
        eid += n
        dropped = ring.push(ids, ids, ids.astype(float))
        assert dropped == max(0, n - capacity)
        retained.extend(ids[n - capacity:] if n > capacity else ids)
        u, _, _, end = ring.window_since(seen, -np.inf)
        assert end >= seen                    # watermark never regresses
        assert end == len(retained)           # single-writer: all visible
        expect = retained[max(seen, end - capacity):end]
        np.testing.assert_array_equal(u, expect)
        delivered.extend(u.tolist())
        seen = end
    # nothing delivered twice; full-stream read clamps to the window
    assert len(delivered) == len(set(delivered))
    u, _, _, _ = ring.window_since(0, -np.inf)
    np.testing.assert_array_equal(
        u, retained[max(0, len(retained) - capacity):])


# ---------------------------------------------------------------------------
# the lost-event swap race, deterministically
# ---------------------------------------------------------------------------

def _mk_snapshot(rng, version, n_users, n_items, flip):
    sizes = (4, 2)
    n_clusters = 8
    flat = ((np.arange(n_users) + 3 * flip) % n_clusters).astype(np.int64)
    ptr, ids = derive_members(flat, n_clusters)
    codes = np.stack([flat // 2, flat % 2], axis=1).astype(np.int32)
    i2i = ((np.arange(n_items)[:, None] + 1 + flip * 7)
           % n_items).astype(np.int64).repeat(3, axis=1)
    return IndexSnapshot(
        user_codes=codes, item_codes=np.zeros((n_items, 2), np.int32),
        user_clusters=flat, member_ptr=ptr, member_ids=ids,
        coarse_codebook=np.zeros((4, 4), np.float32), i2i=i2i,
        version=version, n_users=n_users, n_items=n_items,
        codebook_sizes=sizes)


def test_injected_ingest_between_catchup_and_flip_is_not_lost():
    """The historical race, pinned: an ingest that lands *between* the
    swap's catch-up read and the flip used to be written only to the
    old bundle's store.  The pre-flip hook injects exactly there; the
    post-flip ring drain must deliver it to the new bundle."""
    rng = np.random.default_rng(3)
    n_users, n_items = 40, 30
    snap_a = _mk_snapshot(rng, 1, n_users, n_items, flip=0)
    snap_b = _mk_snapshot(rng, 2, n_users, n_items, flip=1)
    server = SwapServer(snap_a, queue_len=16, recency_s=1e9)
    base = (rng.integers(0, n_users, 400), rng.integers(0, n_items, 400),
            np.sort(rng.random(400) * 100.0))
    server.ingest(*base)
    injected = (np.arange(12) % n_users, np.arange(12) % n_items,
                200.0 + np.arange(12.0))      # newer than every base event

    def hook():
        server._pre_flip_hook = None          # fire exactly once
        server.ingest(*injected)

    server._pre_flip_hook = hook
    rep = server.swap_to(snap_b, now=300.0)
    assert rep["to_version"] == 2.0
    assert rep["replayed_events"] == 400 + 12  # true count, incl. the race
    assert rep["dropped_stale"] == 0.0
    assert rep["ring_dropped"] == 0.0

    oracle = ClusterQueueStore(snap_b.user_clusters, queue_len=16,
                               recency_s=1e9,
                               n_clusters=snap_b.n_clusters)
    oracle.ingest(np.concatenate([base[0], injected[0]]),
                  np.concatenate([base[1], injected[1]]),
                  np.concatenate([base[2], injected[2]]))
    users = np.arange(n_users)
    got, ver = server.retrieve_batch(users, 300.0, 8)
    assert ver == 2
    np.testing.assert_array_equal(got,
                                  oracle.retrieve_batch(users, 300.0, 8))


def test_swap_report_true_replay_count_and_stale_drop():
    """``replayed_events`` counts events actually drained into the new
    bundle (not ring-buffer write totals) and ``dropped_stale`` counts
    window events the recency cutoff discarded."""
    rng = np.random.default_rng(5)
    n_users, n_items = 30, 20
    snap_a = _mk_snapshot(rng, 1, n_users, n_items, flip=0)
    snap_b = _mk_snapshot(rng, 2, n_users, n_items, flip=1)
    server = SwapServer(snap_a, queue_len=8, recency_s=50.0)
    # 100 stale (ts < now - recency) + 60 fresh events
    server.ingest(rng.integers(0, n_users, 100),
                  rng.integers(0, n_items, 100),
                  np.sort(rng.random(100) * 40.0))
    server.ingest(rng.integers(0, n_users, 60),
                  rng.integers(0, n_items, 60),
                  60.0 + np.sort(rng.random(60) * 30.0))
    rep = server.swap_to(snap_b, now=100.0)
    assert rep["replayed_events"] == 60.0
    assert rep["dropped_stale"] == 100.0
    assert rep["ring_dropped"] == 0.0

    # push-truncation drops surface in the next swap report
    big = 1 << 17                              # > default ring capacity
    server.ingest(np.zeros(big, np.int64), np.zeros(big, np.int64),
                  np.full(big, 99.0))
    assert server.ring_dropped == big - server.ring.capacity
    rep2 = server.swap_to(snap_a, now=100.0)
    assert rep2["ring_dropped"] == float(big - server.ring.capacity)


def test_sharded_swap_storm_consistent_versions_and_no_lost_events():
    """Swap storm over a 3-shard store: writers and fused-serve readers
    race two hot swaps.  Every response must be internally
    version-consistent (its union recomputes bitwise from the returned
    version's i2i table — a bundle mixing versions would not), and after
    the storm every shard must hold exactly what a sharded oracle fed
    the same stream holds (zero lost, zero duplicated, per shard)."""
    rng = np.random.default_rng(11)
    n_users, n_items, n_shards = 48, 40, 3
    snaps = [_mk_snapshot(rng, v, n_users, n_items, flip=v % 2)
             for v in (1, 2, 3)]
    server = SwapServer(snaps[0], queue_len=16, recency_s=1e9,
                        n_shards=n_shards)
    assert len(server.handle.acquire().store.partitions()) == n_shards
    i2i_by_ver = {s.version: s.i2i for s in snaps}
    per_writer = [[] for _ in range(2)]
    errs = []

    def writer(w):
        # writer w owns users with u % 2 == w: disjoint clusters under
        # both flip parities, strictly increasing ts within the writer,
        # so per-cluster apply order is deterministic across drains
        try:
            r = np.random.default_rng(100 + w)
            for step in range(40):
                n = int(r.integers(1, 10))
                u = (r.integers(0, n_users // 2, n) * 2 + w).astype(np.int64)
                it = r.integers(0, n_items, n).astype(np.int64)
                ts = (step * 16 + np.arange(n)) * 2.0 + w
                per_writer[w].append((u, it, ts))
                server.ingest(u, it, ts)
        except Exception as e:                  # pragma: no cover
            errs.append(e)

    def reader():
        try:
            r = np.random.default_rng(7)
            for _ in range(30):
                users = r.integers(0, n_users, 16)
                seeds, union, ver = server.serve_batch(
                    users, now=1e6, n_recent=4, k=8)
                np.testing.assert_array_equal(
                    union, u2i2i_retrieve_batch(i2i_by_ver[ver], seeds, 8))
        except Exception as e:                  # pragma: no cover
            errs.append(e)

    threads = ([threading.Thread(target=writer, args=(w,))
                for w in range(2)]
               + [threading.Thread(target=reader) for _ in range(2)])
    for t in threads:
        t.start()
    for snap in snaps[1:]:                      # the storm races the I/O
        time.sleep(0.02)
        server.swap_to(snap, now=1e6)
    for t in threads:
        t.join()
    assert not errs, errs

    final = server.handle.acquire()
    assert final.version == 3
    ev = [np.concatenate(arrs) for arrs in
          zip(*(batch for batches in per_writer for batch in batches))]
    order = np.argsort(ev[2], kind="stable")
    oracle = ShardedQueueStore(snaps[-1].user_clusters,
                               n_shards=n_shards, queue_len=16,
                               recency_s=1e9,
                               n_clusters=snaps[-1].n_clusters)
    oracle.ingest(ev[0][order], ev[1][order], ev[2][order])
    users = np.arange(n_users)
    np.testing.assert_array_equal(
        final.store.retrieve_batch(users, 1e6, 16),
        oracle.retrieve_batch(users, 1e6, 16))
    for got, want in zip(final.store.partitions(), oracle.partitions()):
        np.testing.assert_array_equal(got.cursor, want.cursor)
    assert int(final.store.cursor.sum()) == ev[0].size


# ---------------------------------------------------------------------------
# seqlock telemetry: retry / fallback counters (host engine white-box)
# ---------------------------------------------------------------------------

def test_seqlock_retry_counter_counts_gen_moves():
    """White-box determinism: a read whose generations move underneath
    it retries exactly once and ticks ``serving.seqlock_retries``, and
    the returned value comes from the consistent re-read."""
    tel = Telemetry()                         # NullSink: metrics only
    store = HostQueueStore(np.array([0]), queue_len=8,
                           recency_s=1e9, telemetry=tel)
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] == 1:
            store.gen[0] += 2    # still even, but *moved*: torn read
        return calls["n"]

    assert store._seqlock_read(np.array([0]), fn) == 2
    counters = tel.snapshot()["counters"]
    assert counters["serving.seqlock_retries"] == 1.0
    assert "serving.seqlock_fallbacks" not in counters


def test_seqlock_odd_gen_exhausts_spins_then_falls_back():
    """A generation stuck odd (writer mid-flight forever) burns the
    whole spin budget — every collision counted — then takes exactly
    one locked fallback."""
    tel = Telemetry()
    store = HostQueueStore(np.array([0]), queue_len=8,
                           recency_s=1e9, telemetry=tel)
    store.gen[0] = 1                          # permanently mid-flight
    assert store._seqlock_read(np.array([0]), lambda: 9) == 9
    counters = tel.snapshot()["counters"]
    assert counters["serving.seqlock_retries"] == float(
        store._SEQLOCK_SPINS)
    assert counters["serving.seqlock_fallbacks"] == 1.0


def test_seqlock_fallback_counter_and_retrieve_metrics():
    """The forced-fallback path (zero spin budget) ticks the fallback
    counter but no retries; the retrieve wrapper records the request
    count and a latency observation either way."""
    tel = Telemetry()
    store = HostQueueStore(np.array([0, 1]), queue_len=8,
                           recency_s=1e9, telemetry=tel)
    store.ingest(np.array([0, 1]), np.array([5, 6]),
                 np.array([1.0, 2.0]))
    store._SEQLOCK_SPINS = 0
    assert store.retrieve(0, 10.0, 4) == [5]
    snap = tel.snapshot()
    assert snap["counters"]["serving.seqlock_fallbacks"] == 1.0
    assert snap["counters"]["serving.retrieve_requests"] == 1.0
    assert "serving.seqlock_retries" not in snap["counters"]
    assert snap["counters"]["serving.ingest_events"] == 2.0
    assert snap["hists"]["serving.retrieve_latency_s"]["n"] == 1
    assert snap["gauges"]["serving.queue_depth_max"] == 1.0


def test_seqlock_counters_move_under_writer_racing_readers():
    """The satellite contract: under a writer-racing-readers workload
    the retry counter actually moves.  The writer holds every cluster's
    generation odd for a beat per iteration (the mid-flight window a
    real scatter would occupy), so overlapping readers must observe the
    collision and retry or fall back — and every request still
    completes and is counted."""
    tel = Telemetry()
    n_users, C = 64, 8
    store = HostQueueStore(np.arange(n_users) % C, queue_len=16,
                           recency_s=1e9, telemetry=tel)
    store.ingest(np.arange(n_users), np.arange(n_users),
                 np.arange(n_users, dtype=float))
    stop = threading.Event()
    errs = []

    def writer():
        try:
            while not stop.is_set():
                with store.write_lock:
                    store.gen += 1            # enter: odd, readers spin
                    time.sleep(2e-4)
                    store.gen += 1            # exit: even again
                time.sleep(0)                 # let readers through
        except Exception as e:                # pragma: no cover
            errs.append(e)

    def reader():
        try:
            users = np.arange(n_users)
            for _ in range(150):
                out = store.retrieve_batch(users, 1e6, 8)
                assert out.shape == (n_users, 8)
        except Exception as e:                # pragma: no cover
            errs.append(e)

    wt = threading.Thread(target=writer)
    rts = [threading.Thread(target=reader) for _ in range(2)]
    wt.start()
    for t in rts:
        t.start()
    for t in rts:
        t.join()
    stop.set()
    wt.join()
    assert not errs, errs
    counters = tel.snapshot()["counters"]
    assert counters["serving.retrieve_requests"] == 300.0
    assert counters.get("serving.seqlock_retries", 0.0) > 0.0
    hist = tel.snapshot()["hists"]["serving.retrieve_latency_s"]
    assert hist["n"] == 300


def test_swap_telemetry_counters_and_span_join_key():
    """``swap_to`` under an enabled telemetry instance: the stall spans
    land in the trace, the replay/drop counters match the swap report,
    and the report's ``span_id`` joins to the ``lifecycle.swap`` span
    record."""
    import json

    sink = MemorySink()
    tel = Telemetry(sink=sink, clock=FixedClock())
    rng = np.random.default_rng(11)
    n_users, n_items = 30, 20
    snap_a = _mk_snapshot(rng, 1, n_users, n_items, flip=0)
    snap_b = _mk_snapshot(rng, 2, n_users, n_items, flip=1)
    server = SwapServer(snap_a, queue_len=8, recency_s=1e9,
                        telemetry=tel)
    server.ingest(rng.integers(0, n_users, 50),
                  rng.integers(0, n_items, 50),
                  np.sort(rng.random(50) * 40.0))
    rep = server.swap_to(snap_b, now=100.0)

    counters = tel.snapshot()["counters"]
    assert counters["swap.replayed_events"] == rep["replayed_events"]
    assert counters["swap.dropped_stale"] == rep["dropped_stale"]
    assert "swap.ring_dropped" not in counters    # nothing overflowed

    recs = [json.loads(ln) for ln in sink.lines]
    spans = {r["name"]: r for r in recs if r["type"] == "span"}
    for name in ("swap.build", "swap.replay", "swap.catchup",
                 "swap.flip", "swap.post_drain", "lifecycle.swap"):
        assert name in spans, name
    root = spans["lifecycle.swap"]
    assert rep["span_id"] == float(root["span_id"])
    for name in ("swap.catchup", "swap.flip", "swap.post_drain"):
        assert spans[name]["parent_id"] == root["span_id"]
    assert root["attrs"]["to_version"] == 2
