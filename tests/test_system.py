"""End-to-end behaviour tests for the paper's system (integration)."""
import dataclasses as dc

import numpy as np
import pytest

from repro.configs.base import RankGraph2Config, RQConfig
from repro.core import evaluation as EV
from repro.core.pipeline import run_pipeline
from repro.data.synthetic import make_world


@pytest.fixture(scope="module")
def sys_world():
    # the validated benchmark world: sparse engagement over a large item
    # space + high feature noise, so the *graph* carries the signal and
    # the recall metric keeps dynamic range (see benchmarks/common.py)
    return make_world(n_users=700, n_items=1800, events_per_user=14.0,
                      feat_noise=1.8, pop_strength=0.5, temp=0.12, seed=7)


@pytest.fixture(scope="module")
def sys_result(sys_world):
    cfg = RankGraph2Config(
        d_user_feat=64, d_item_feat=64, d_embed=48, n_heads=2, d_hidden=128,
        k_imp=20, k_train=8, n_negatives=50, n_pool_neg=16, k_cap=32,
        ppr_walks=32, ppr_len=4, ppr_restart=0.3,
        rq=RQConfig(codebook_sizes=(64, 16), hist_len=100), dtype="float32")
    return run_pipeline(sys_world, cfg, steps=300, batch_per_type=96,
                        seed=1)


def test_pipeline_produces_embeddings(sys_result, sys_world):
    r = sys_result
    assert r.user_emb.shape == (sys_world.n_users, 48)
    assert r.item_emb.shape == (sys_world.n_items, 48)
    assert np.isfinite(r.user_emb).all() and np.isfinite(r.item_emb).all()
    assert r.user_codes.shape == (sys_world.n_users,)
    assert r.user_codes.min() >= 0 and r.user_codes.max() < 64 * 16


def test_learned_embeddings_beat_random(sys_result, sys_world):
    rng = np.random.default_rng(0)
    rand = rng.normal(size=sys_result.user_emb.shape)
    learned = EV.user_recall(sys_result.user_emb, sys_world, n_queries=200)
    random = EV.user_recall(rand, sys_world, n_queries=200)
    assert learned[5] > random[5] * 1.2, (learned, random)


def test_item_embeddings_capture_coengagement(sys_result, sys_world):
    rng = np.random.default_rng(0)
    rand = rng.normal(size=sys_result.item_emb.shape)
    learned = EV.item_recall(sys_result.item_emb, sys_world, n_edges=300)
    random = EV.item_recall(rand, sys_world, n_edges=300)
    assert learned[100] > random[100] * 1.2, (learned, random)


def test_cluster_serving_end_to_end(sys_result, sys_world):
    from repro.core.serving import ClusterQueueStore
    store = ClusterQueueStore(sys_result.user_codes, recency_s=86400.0)
    d1 = sys_world.day1
    store.ingest(d1.user_id, d1.item_id, d1.timestamp)
    now = float(d1.timestamp.max())
    day1_items = EV._user_day1_items(sys_world.day1)
    hits = total = served = 0
    for u in range(sys_world.n_users):
        got = store.retrieve(u, now, 64)
        if got:
            served += 1
        if day1_items[u]:
            hits += len(set(got) & day1_items[u])
            total += len(day1_items[u])
    assert served > sys_world.n_users * 0.5
    assert hits / max(total, 1) > 0.05       # real retrieval signal


def test_codebook_utilization_healthy(sys_result):
    from repro.core.rq_index import codebook_utilization
    util = codebook_utilization(sys_result.state.rq_state)
    assert util[0] > 0.5, util                # regularizer keeps codes alive


def test_hour_level_rebuild_freshness(sys_world):
    """The construction path is re-runnable on a shifted window and picks
    up fresh items (hour-level refresh requirement)."""
    from repro.core.graph_builder import build_graph
    g0 = build_graph(sys_world.day0.window(43200.0, 43200.0), k_cap=16)
    g1 = build_graph(sys_world.day0.window(86400.0, 43200.0), k_cap=16)
    assert g0.n_edges > 0 and g1.n_edges > 0
    # different windows -> different co-engagement structure
    assert g0.n_edges != g1.n_edges
