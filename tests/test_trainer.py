"""Training-loop behaviour: convergence, determinism, state plumbing,
dedup/id-only forward equivalence, donated-step checkpointing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import trainer as T
from repro.core import rq_index as RQ
from repro.distributed.sharding import NULL_CTX


def _step_n(state, step_fn, ds, per_type, seed, n, start=0, format=None):
    m = None
    for t in range(start, start + n):
        batch = jax.tree.map(jnp.asarray,
                             ds.sample_batch(t, seed, per_type,
                                             format=format))
        state, m = step_fn(state, batch, jax.random.key(500 + t))
    return state, m


def test_loss_decreases(tiny_cfg, tiny_dataset):
    state, specs, optimizer = T.init_state(jax.random.key(0), tiny_cfg,
                                           pool_size=256)
    step = T.make_train_step(tiny_cfg, optimizer)
    per_type = {"uu": 32, "ui": 32, "ii": 32}
    state, m0 = _step_n(state, step, tiny_dataset, per_type, 0, 3)
    state, m1 = _step_n(state, step, tiny_dataset, per_type, 0, 40, start=3)
    assert float(m1["infonce_ui"]) < float(m0["infonce_ui"])
    assert np.isfinite(float(m1["total"]))


def test_state_advances_and_pool_fills(tiny_cfg, tiny_dataset):
    state, _, optimizer = T.init_state(jax.random.key(0), tiny_cfg,
                                       pool_size=256)
    step = T.make_train_step(tiny_cfg, optimizer)
    per_type = {"uu": 16, "ui": 16, "ii": 16}
    state, _ = _step_n(state, step, tiny_dataset, per_type, 0, 2)
    assert int(state.step) == 2
    assert int(state.pool.user_fill) > 0
    assert int(state.pool.item_fill) > 0
    assert int(state.rq_state.ptr) == 2


def test_deterministic_resume(tiny_cfg, tiny_dataset):
    """batch(seed, t) purity + identical keys => identical training —
    the checkpoint-resume invariant."""
    per_type = {"uu": 16, "ui": 16, "ii": 16}

    def run(n, state=None):
        if state is None:
            state, _, opt = T.init_state(jax.random.key(0), tiny_cfg,
                                         pool_size=128)
        else:
            _, _, opt = T.init_state(jax.random.key(0), tiny_cfg,
                                     pool_size=128)
        step = T.make_train_step(tiny_cfg, opt)
        start = int(state.step)
        return _step_n(state, step, tiny_dataset, per_type, 0, n,
                       start=start)[0]

    s_full = run(8)
    s_half = run(4)
    s_resumed = run(4, state=s_half)
    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_uncertainty_weights_move(tiny_cfg, tiny_dataset):
    state, _, optimizer = T.init_state(jax.random.key(0), tiny_cfg,
                                       pool_size=128)
    before = {k: float(v) for k, v in
              state.params["uncertainty"].items()}
    step = T.make_train_step(tiny_cfg, optimizer)
    state, _ = _step_n(state, step, tiny_dataset,
                       {"uu": 16, "ui": 16, "ii": 16}, 0, 10)
    after = {k: float(v) for k, v in state.params["uncertainty"].items()}
    assert any(abs(after[k] - before[k]) > 1e-4 for k in after)


def test_embed_all_shapes(tiny_cfg, tiny_dataset, tiny_graph):
    state, _, _ = T.init_state(jax.random.key(0), tiny_cfg, pool_size=64)
    from repro.core import model as M
    emb = T.embed_all(state.params, tiny_cfg, tiny_dataset,
                      node_type=M.USER, ids=np.arange(50), batch=32)
    assert emb.shape == (50, tiny_cfg.d_embed)
    norms = np.linalg.norm(emb, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-3)
    # corpus smaller than one batch: the first chunk pads too (one trace
    # per batch size, not one per corpus size)
    emb_small = T.embed_all(state.params, tiny_cfg, tiny_dataset,
                            node_type=M.USER, ids=np.arange(7), batch=32)
    np.testing.assert_allclose(emb_small, emb[:7], rtol=1e-6)


# ---------------------------------------------------------------------------
# dedup / id-only forward equivalence (the PR-4 hot-path rework)
# ---------------------------------------------------------------------------

def _forward_tasks(cfg, state, batch, features=None):
    tasks, _ = T._forward_losses(state.params, cfg,
                                 jax.tree.map(jnp.asarray, batch),
                                 state.pool, state.rq_state,
                                 jax.random.key(99), NULL_CTX, True,
                                 features)
    return {k: float(v) for k, v in tasks.items()}


def test_dedup_forward_matches_legacy_forward(tiny_cfg, tiny_dataset):
    """Unique-node forward == per-endpoint PR-3 forward on the same
    edge draws (expand_batch re-materializes the legacy view)."""
    state, _, _ = T.init_state(jax.random.key(0), tiny_cfg, pool_size=128)
    per_type = {"uu": 16, "ui": 16, "ii": 16}
    dedup = tiny_dataset.sample_batch(7, 0, per_type, format="dedup")
    legacy = tiny_dataset.expand_batch(dedup)
    td = _forward_tasks(tiny_cfg, state, dedup)
    tl = _forward_tasks(tiny_cfg, state, legacy)
    assert set(td) == set(tl)
    for k in td:
        np.testing.assert_allclose(td[k], tl[k], rtol=1e-5, atol=1e-6,
                                   err_msg=k)


def test_id_only_forward_matches_feat_forward(tiny_cfg, tiny_dataset):
    state, _, _ = T.init_state(jax.random.key(0), tiny_cfg, pool_size=128)
    per_type = {"uu": 16, "ui": 16, "ii": 16}
    feats = T.make_feature_store(tiny_dataset.user_feat,
                                 tiny_dataset.item_feat)
    bf = tiny_dataset.sample_batch(9, 0, per_type, format="dedup")
    bi = tiny_dataset.sample_batch(9, 0, per_type, format="dedup_ids")
    tf = _forward_tasks(tiny_cfg, state, bf)
    ti = _forward_tasks(tiny_cfg, state, bi, features=feats)
    for k in tf:
        np.testing.assert_allclose(tf[k], ti[k], rtol=1e-6, atol=1e-7,
                                   err_msg=k)


def test_id_only_pipeline_trains_identically(tiny_cfg, tiny_dataset):
    """Full jitted steps: feat-mode dedup vs id-only device-gather land
    on the same parameters."""
    per_type = {"uu": 16, "ui": 16, "ii": 16}

    def run(fmt, features=None):
        state, _, opt = T.init_state(jax.random.key(0), tiny_cfg,
                                     pool_size=128)
        step = T.make_train_step(tiny_cfg, opt, features=features)
        return _step_n(state, step, tiny_dataset, per_type, 0, 4,
                       format=fmt)[0]

    feats = T.make_feature_store(tiny_dataset.user_feat,
                                 tiny_dataset.item_feat)
    s_feat = run("dedup")
    s_ids = run("dedup_ids", features=feats)
    for a, b in zip(jax.tree.leaves(s_feat.params),
                    jax.tree.leaves(s_ids.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_id_only_without_store_raises(tiny_cfg, tiny_dataset):
    state, _, opt = T.init_state(jax.random.key(0), tiny_cfg, pool_size=64)
    step = T.make_train_step(tiny_cfg, opt)
    batch = jax.tree.map(jnp.asarray, tiny_dataset.sample_batch(
        0, 0, {"ui": 8}, format="dedup_ids"))
    with pytest.raises(ValueError, match="FeatureStore"):
        step(state, batch, jax.random.key(0))


def test_lprime_negative_reuse_flag(tiny_cfg, tiny_dataset):
    """Reused negatives change only the L' task (raw losses share keys);
    the PR-3 double-draw is restorable for old-run reproducibility."""
    state, _, _ = T.init_state(jax.random.key(0), tiny_cfg, pool_size=128)
    # a filled pool makes the second draw actually differ
    pool = state.pool
    k1, k2 = jax.random.split(jax.random.key(5))
    from repro.core import negatives as N
    pool = N.update_pool(pool, jax.random.normal(k1, (64, tiny_cfg.d_embed)),
                         jax.random.normal(k2, (64, tiny_cfg.d_embed)))
    state = dataclasses.replace(state, pool=pool)
    batch = tiny_dataset.sample_batch(3, 0, {"uu": 16, "ui": 16, "ii": 16})
    cfg_old = dataclasses.replace(tiny_cfg, reuse_lprime_negatives=False)
    t_new = _forward_tasks(tiny_cfg, state, batch)
    t_old = _forward_tasks(cfg_old, state, batch)
    for k in t_new:
        if k.startswith(("margin_", "infonce_")) or k in ("rq_recon",
                                                          "rq_reg"):
            np.testing.assert_allclose(t_new[k], t_old[k], rtol=1e-6,
                                       err_msg=k)
    assert abs(t_new["rq_contrastive"] - t_old["rq_contrastive"]) > 1e-7


def test_fused_kernel_step_matches_reference(tiny_cfg, tiny_dataset):
    """cfg.use_fused_contrastive routes pair losses through the Pallas
    custom-VJP kernel under value_and_grad; parameters after a step must
    match the jnp path."""
    per_type = {"uu": 8, "ui": 8, "ii": 8}

    def run(cfg):
        state, _, opt = T.init_state(jax.random.key(0), cfg, pool_size=64)
        step = T.make_train_step(cfg, opt)
        return _step_n(state, step, tiny_dataset, per_type, 0, 2)[0]

    s_ref = run(tiny_cfg)
    s_ker = run(dataclasses.replace(tiny_cfg, use_fused_contrastive=True))
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s_ker.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)


def test_donated_step_checkpoint_roundtrip(tmp_path, tiny_cfg,
                                           tiny_dataset):
    """The donated jitted step + Checkpointer round-trip: save mid-run,
    restore into fresh buffers, resume — identical to an uninterrupted
    run (the donate_argnums=0 migration must not break fault
    tolerance)."""
    from repro.checkpoint.checkpointer import Checkpointer
    per_type = {"uu": 8, "ui": 8, "ii": 8}
    state, _, opt = T.init_state(jax.random.key(0), tiny_cfg, pool_size=64)
    step = T.make_train_step(tiny_cfg, opt)
    s_full, _ = _step_n(state, step, tiny_dataset, per_type, 0, 6)

    state2, _, opt2 = T.init_state(jax.random.key(0), tiny_cfg,
                                   pool_size=64)
    step2 = T.make_train_step(tiny_cfg, opt2)
    s_half, _ = _step_n(state2, step2, tiny_dataset, per_type, 0, 3)
    ck = Checkpointer(str(tmp_path))
    ck.save(int(s_half.step), s_half, metadata={"data_seed": 0})
    like = jax.tree.map(jnp.zeros_like, s_half)
    restored, meta = ck.restore(like)
    assert int(restored.step) == 3
    s_resumed, _ = _step_n(restored, step2, tiny_dataset, per_type, 0, 3,
                           start=3)
    for a, b in zip(jax.tree.leaves(s_full), jax.tree.leaves(s_resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_donated_step_survives_dead_code_reset(tiny_cfg, tiny_dataset):
    """The self-healing pass swaps codebook rows host-side mid-burst;
    the already-compiled donated step must keep running on the new
    state (same pytree structure/dtypes), and optimizer moments must
    ride through the functional swap untouched."""
    state, _, opt = T.init_state(jax.random.key(0), tiny_cfg,
                                 pool_size=128)
    step = T.make_train_step(tiny_cfg, opt)
    per_type = {"uu": 16, "ui": 16, "ii": 16}
    state, _ = _step_n(state, step, tiny_dataset, per_type, 0, 2)
    probe = np.random.default_rng(0).normal(
        size=(64, tiny_cfg.d_embed)).astype(np.float32)
    sizes = tiny_cfg.rq.codebook_sizes
    usage = [np.r_[np.ones(n // 2), np.zeros(n - n // 2)]
             .astype(np.float32) for n in sizes]
    opt_before = [np.asarray(x) for x in jax.tree.leaves(state.opt_state)]
    books_before = [np.asarray(state.params["rq"]["codebooks"][f"layer{l}"])
                    for l in range(len(sizes))]
    state, rep = T.reset_dead_codes(state, probe, tiny_cfg, seed=3,
                                    usage=usage)
    assert sum(rep.values()) == sum(n - n // 2 for n in sizes)
    for a, b in zip(opt_before, jax.tree.leaves(state.opt_state)):
        np.testing.assert_array_equal(a, np.asarray(b))
    for l, n in enumerate(sizes):                  # live rows untouched
        after = np.asarray(state.params["rq"]["codebooks"][f"layer{l}"])
        np.testing.assert_array_equal(books_before[l][: n // 2],
                                      after[: n // 2])
        assert not np.array_equal(books_before[l][n // 2:],
                                  after[n // 2:])
    state, m = _step_n(state, step, tiny_dataset, per_type, 0, 2, start=2)
    assert int(state.step) == 4
    assert np.isfinite(float(m["total"]))
