"""Training-loop behaviour: convergence, determinism, state plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import trainer as T
from repro.core import rq_index as RQ


def _step_n(state, step_fn, ds, per_type, seed, n, start=0):
    m = None
    for t in range(start, start + n):
        batch = jax.tree.map(jnp.asarray, ds.sample_batch(t, seed, per_type))
        state, m = step_fn(state, batch, jax.random.key(500 + t))
    return state, m


def test_loss_decreases(tiny_cfg, tiny_dataset):
    state, specs, optimizer = T.init_state(jax.random.key(0), tiny_cfg,
                                           pool_size=256)
    step = jax.jit(T.make_train_step(tiny_cfg, optimizer))
    per_type = {"uu": 32, "ui": 32, "ii": 32}
    state, m0 = _step_n(state, step, tiny_dataset, per_type, 0, 3)
    state, m1 = _step_n(state, step, tiny_dataset, per_type, 0, 40, start=3)
    assert float(m1["infonce_ui"]) < float(m0["infonce_ui"])
    assert np.isfinite(float(m1["total"]))


def test_state_advances_and_pool_fills(tiny_cfg, tiny_dataset):
    state, _, optimizer = T.init_state(jax.random.key(0), tiny_cfg,
                                       pool_size=256)
    step = jax.jit(T.make_train_step(tiny_cfg, optimizer))
    per_type = {"uu": 16, "ui": 16, "ii": 16}
    state, _ = _step_n(state, step, tiny_dataset, per_type, 0, 2)
    assert int(state.step) == 2
    assert int(state.pool.user_fill) > 0
    assert int(state.pool.item_fill) > 0
    assert int(state.rq_state.ptr) == 2


def test_deterministic_resume(tiny_cfg, tiny_dataset):
    """batch(seed, t) purity + identical keys => identical training —
    the checkpoint-resume invariant."""
    per_type = {"uu": 16, "ui": 16, "ii": 16}

    def run(n, state=None):
        if state is None:
            state, _, opt = T.init_state(jax.random.key(0), tiny_cfg,
                                         pool_size=128)
        else:
            _, _, opt = T.init_state(jax.random.key(0), tiny_cfg,
                                     pool_size=128)
        step = jax.jit(T.make_train_step(tiny_cfg, opt))
        start = int(state.step)
        return _step_n(state, step, tiny_dataset, per_type, 0, n,
                       start=start)[0]

    s_full = run(8)
    s_half = run(4)
    s_resumed = run(4, state=s_half)
    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_uncertainty_weights_move(tiny_cfg, tiny_dataset):
    state, _, optimizer = T.init_state(jax.random.key(0), tiny_cfg,
                                       pool_size=128)
    before = {k: float(v) for k, v in
              state.params["uncertainty"].items()}
    step = jax.jit(T.make_train_step(tiny_cfg, optimizer))
    state, _ = _step_n(state, step, tiny_dataset,
                       {"uu": 16, "ui": 16, "ii": 16}, 0, 10)
    after = {k: float(v) for k, v in state.params["uncertainty"].items()}
    assert any(abs(after[k] - before[k]) > 1e-4 for k in after)


def test_embed_all_shapes(tiny_cfg, tiny_dataset, tiny_graph):
    state, _, _ = T.init_state(jax.random.key(0), tiny_cfg, pool_size=64)
    from repro.core import model as M
    emb = T.embed_all(state.params, tiny_cfg, tiny_dataset,
                      node_type=M.USER, ids=np.arange(50), batch=32)
    assert emb.shape == (50, tiny_cfg.d_embed)
    norms = np.linalg.norm(emb, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-3)
