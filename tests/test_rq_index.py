"""Co-learned RQ index tests (paper §4.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RQConfig
from repro.core import rq_index as RQ


def _setup(sizes=(16, 8), d=12, B=64, seed=0):
    cfg = RQConfig(codebook_sizes=sizes, hist_len=10)
    params, specs, state = RQ.init_rq(jax.random.key(seed), cfg, d)
    h = jax.random.normal(jax.random.key(seed + 1), (B, d))
    return cfg, params, state, h


def test_forward_shapes_and_losses():
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h, cfg)
    assert out["codes"].shape == (64, 2)
    assert out["recon"].shape == h.shape
    assert float(out["l_recon"]) > 0
    assert np.isfinite(float(out["l_reg"]))
    # state advanced
    assert int(out["state"].ptr) == 1


def test_reconstruction_improves_with_training():
    cfg, params, state, h = _setup()
    from repro.optim.optimizers import adamw, apply_updates
    opt = adamw(5e-2, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, state):
        def loss(p):
            out = RQ.rq_forward(p, state, h, cfg)
            return out["l_recon"], out["state"]
        (l, new_state), g = jax.value_and_grad(loss, has_aux=True)(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, new_state, l

    # judge codebook quality in eval mode (nearest assignment, Eq. 9):
    # the *training* loss is non-monotone by design — biased selection
    # (Eq. 13) keeps re-routing points to under-used codes as the
    # rolling histogram fills, so a fixed-step snapshot of it is flaky
    l0 = float(RQ.rq_forward(params, state, h, cfg,
                             train=False)["l_recon"])
    for t in range(60):
        params, opt_state, state, l = step(params, opt_state, state)
    l_eval = float(RQ.rq_forward(params, state, h, cfg,
                                 train=False)["l_recon"])
    assert l_eval < 0.5 * l0, (l0, l_eval)


def test_recon_equals_sum_of_selected_codes():
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h, cfg, train=False)
    rec = RQ.reconstruct(params, out["codes"], cfg)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(out["recon"]),
                               rtol=1e-5, atol=1e-6)


def test_unbiased_assignment_is_nearest():
    """With biased_selection off, Eq. 9 argmin must hold per layer."""
    cfg, params, state, h = _setup()
    import dataclasses as dc
    cfg = dc.replace(cfg, biased_selection=False)
    out = RQ.rq_forward(params, state, h, cfg, train=True)
    C0 = np.asarray(params["codebooks"]["layer0"])
    d2 = ((np.asarray(h)[:, None] - C0[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(np.asarray(out["codes"][:, 0]),
                                  d2.argmin(1))


def test_biased_selection_favors_underused_codes():
    cfg, params, state, h = _setup(sizes=(8,))
    # fake history: code 0 used overwhelmingly
    hist = state.hists[0].at[:, 0].set(100.0)
    state = RQ.RQState((hist,), state.ptr, state.filled)
    out_b = RQ.rq_forward(params, state, h, cfg, train=True)
    import dataclasses as dc
    out_u = RQ.rq_forward(params, state, h,
                          dc.replace(cfg, biased_selection=False))
    used_b = np.bincount(np.asarray(out_b["codes"][:, 0]), minlength=8)
    used_u = np.bincount(np.asarray(out_u["codes"][:, 0]), minlength=8)
    assert used_b[0] <= used_u[0]      # over-used code gets de-prioritized


def test_assign_codes_flat_roundtrip():
    cfg, params, state, h = _setup(sizes=(5, 3))
    flat = np.asarray(RQ.assign_codes(params, h, cfg))
    assert flat.min() >= 0 and flat.max() < 15
    # agrees with unbiased forward
    import dataclasses as dc
    out = RQ.rq_forward(params, state, h,
                        dc.replace(cfg, biased_selection=False),
                        train=False)
    codes = np.asarray(out["codes"])
    np.testing.assert_array_equal(flat, codes[:, 0] * 3 + codes[:, 1])


def test_codebook_utilization_range():
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h, cfg)
    util = RQ.codebook_utilization(out["state"])
    assert all(0.0 <= u <= 1.0 for u in util)
    assert util[0] > 0


def test_regularizer_zero_when_disabled():
    import dataclasses as dc
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h,
                        dc.replace(cfg, regularize=False))
    assert float(out["l_reg"]) == 0.0


def test_straight_through_gradient_reaches_encoder():
    cfg, params, state, h = _setup()

    def f(h):
        out = RQ.rq_forward(params, state, h, cfg)
        return jnp.sum(out["recon_st"] ** 2)

    g = jax.grad(f)(h)
    assert float(jnp.abs(g).sum()) > 0
