"""Co-learned RQ index tests (paper §4.4) + index-health properties:
assignment-range / residual-cascade invariants, published-utilization
semantics, and the dead-code reset guarantees the self-healing
lifecycle leans on."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.configs.base import RQConfig
from repro.core import rq_index as RQ


def _setup(sizes=(16, 8), d=12, B=64, seed=0):
    cfg = RQConfig(codebook_sizes=sizes, hist_len=10)
    params, specs, state = RQ.init_rq(jax.random.key(seed), cfg, d)
    h = jax.random.normal(jax.random.key(seed + 1), (B, d))
    return cfg, params, state, h


def test_forward_shapes_and_losses():
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h, cfg)
    assert out["codes"].shape == (64, 2)
    assert out["recon"].shape == h.shape
    assert float(out["l_recon"]) > 0
    assert np.isfinite(float(out["l_reg"]))
    # state advanced
    assert int(out["state"].ptr) == 1


def test_reconstruction_improves_with_training():
    cfg, params, state, h = _setup()
    from repro.optim.optimizers import adamw, apply_updates
    opt = adamw(5e-2, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, state):
        def loss(p):
            out = RQ.rq_forward(p, state, h, cfg)
            return out["l_recon"], out["state"]
        (l, new_state), g = jax.value_and_grad(loss, has_aux=True)(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, new_state, l

    # judge codebook quality in eval mode (nearest assignment, Eq. 9):
    # the *training* loss is non-monotone by design — biased selection
    # (Eq. 13) keeps re-routing points to under-used codes as the
    # rolling histogram fills, so a fixed-step snapshot of it is flaky
    l0 = float(RQ.rq_forward(params, state, h, cfg,
                             train=False)["l_recon"])
    for t in range(60):
        params, opt_state, state, l = step(params, opt_state, state)
    l_eval = float(RQ.rq_forward(params, state, h, cfg,
                                 train=False)["l_recon"])
    assert l_eval < 0.5 * l0, (l0, l_eval)


def test_recon_equals_sum_of_selected_codes():
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h, cfg, train=False)
    rec = RQ.reconstruct(params, out["codes"], cfg)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(out["recon"]),
                               rtol=1e-5, atol=1e-6)


def test_unbiased_assignment_is_nearest():
    """With biased_selection off, Eq. 9 argmin must hold per layer."""
    cfg, params, state, h = _setup()
    import dataclasses as dc
    cfg = dc.replace(cfg, biased_selection=False)
    out = RQ.rq_forward(params, state, h, cfg, train=True)
    C0 = np.asarray(params["codebooks"]["layer0"])
    d2 = ((np.asarray(h)[:, None] - C0[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(np.asarray(out["codes"][:, 0]),
                                  d2.argmin(1))


def test_biased_selection_favors_underused_codes():
    cfg, params, state, h = _setup(sizes=(8,))
    # fake history: code 0 used overwhelmingly
    hist = state.hists[0].at[:, 0].set(100.0)
    state = RQ.RQState((hist,), state.usage, state.ptr, state.filled)
    out_b = RQ.rq_forward(params, state, h, cfg, train=True)
    import dataclasses as dc
    out_u = RQ.rq_forward(params, state, h,
                          dc.replace(cfg, biased_selection=False))
    used_b = np.bincount(np.asarray(out_b["codes"][:, 0]), minlength=8)
    used_u = np.bincount(np.asarray(out_u["codes"][:, 0]), minlength=8)
    assert used_b[0] <= used_u[0]      # over-used code gets de-prioritized


def test_assign_codes_flat_roundtrip():
    cfg, params, state, h = _setup(sizes=(5, 3))
    flat = np.asarray(RQ.assign_codes(params, h, cfg))
    assert flat.min() >= 0 and flat.max() < 15
    # agrees with unbiased forward
    import dataclasses as dc
    out = RQ.rq_forward(params, state, h,
                        dc.replace(cfg, biased_selection=False),
                        train=False)
    codes = np.asarray(out["codes"])
    np.testing.assert_array_equal(flat, codes[:, 0] * 3 + codes[:, 1])


def test_codebook_utilization_range():
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h, cfg)
    util = RQ.codebook_utilization(out["state"])
    assert all(0.0 <= u <= 1.0 for u in util)
    assert util[0] > 0


def test_regularizer_zero_when_disabled():
    import dataclasses as dc
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h,
                        dc.replace(cfg, regularize=False))
    assert float(out["l_reg"]) == 0.0


def test_straight_through_gradient_reaches_encoder():
    cfg, params, state, h = _setup()

    def f(h):
        out = RQ.rq_forward(params, state, h, cfg)
        return jnp.sum(out["recon_st"] ** 2)

    g = jax.grad(f)(h)
    assert float(jnp.abs(g).sum()) > 0


# ---------------------------------------------------------------------------
# utilization-balancing loss (l_util) semantics
# ---------------------------------------------------------------------------

def test_util_loss_orders_collapse_above_balance():
    """The load-balance gap must score a collapsed batch strictly above
    a perfectly spread one, inside [0, util_coef]."""
    d, K = 6, 8
    cfg = RQConfig(codebook_sizes=(K,), hist_len=4, util_coef=1.0,
                   biased_selection=False)
    _, _, state = RQ.init_rq(jax.random.key(0), cfg, d)
    C = np.asarray(jax.random.normal(jax.random.key(1), (K, d)),
                   np.float32)
    params = {"codebooks": {"layer0": jnp.asarray(C)}}
    balanced = jnp.asarray(np.repeat(C, 5, axis=0))    # every code wins
    collapsed = jnp.asarray(np.tile(C[0], (5 * K, 1)))  # code 0 wins all
    lb = float(RQ.rq_forward(params, state, balanced, cfg)["l_util"])
    lc = float(RQ.rq_forward(params, state, collapsed, cfg)["l_util"])
    assert 0.0 <= lb <= 1.0 + 1e-6 and 0.0 <= lc <= 1.0 + 1e-6
    assert lc > lb


def test_util_loss_zero_when_disabled():
    cfg, params, state, h = _setup()
    out = RQ.rq_forward(params, state, h,
                        dc.replace(cfg, util_coef=0.0))
    assert float(out["l_util"]) == 0.0


def test_usage_ema_tracks_argmin_not_routing():
    """EMA usage must reflect Eq. 9 argmin occupancy even when Eq. 13
    biased selection routes the batch elsewhere — routed histograms stay
    flat at full argmin collapse, so they cannot detect a dead code."""
    d, K = 6, 8
    cfg = RQConfig(codebook_sizes=(K,), hist_len=4, usage_ema=0.0,
                   biased_selection=True)
    _, _, state = RQ.init_rq(jax.random.key(0), cfg, d)
    C = np.asarray(jax.random.normal(jax.random.key(1), (K, d)),
                   np.float32)
    params = {"codebooks": {"layer0": jnp.asarray(C)}}
    # bias routing away from code 0 (huge rolling-hist mass on it);
    # points sit NEAR code 0 (not at it — p_soft would saturate and no
    # histogram ratio could outvote it), argmin-closest to it
    state = RQ.RQState((state.hists[0].at[:, 0].set(1e4),),
                       state.usage, state.ptr, state.filled)
    rng = np.random.default_rng(0)
    h = np.tile(C[0], (32, 1)) + rng.normal(
        scale=0.25, size=(32, d)).astype(np.float32)
    d2 = (np.sum(h * h, 1, keepdims=True) - 2 * h @ C.T
          + np.sum(C * C, 1)[None])
    assert (d2.argmin(1) == 0).all()       # construction sanity
    h = jnp.asarray(h)
    out = RQ.rq_forward(params, state, h, cfg, train=True)
    routed = np.bincount(np.asarray(out["codes"][:, 0]), minlength=K)
    assert routed[0] < 32                  # Eq. 13 routed traffic away
    usage = np.asarray(out["state"].usage[0])
    assert usage.argmax() == 0             # ...but usage saw the argmin
    np.testing.assert_allclose(usage[0], 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# index-health properties (hypothesis; skip cleanly without the dep)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 48), st.integers(2, 12), st.integers(0, 2 ** 16))
def test_property_codes_always_in_range(B, d, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    for sizes in ((5,), (7, 3)):
        cfg = RQConfig(codebook_sizes=sizes, hist_len=4)
        params, _, state = RQ.init_rq(jax.random.key(seed % 97), cfg, d)
        for biased in (True, False):
            out = RQ.rq_forward(params, state, h,
                                dc.replace(cfg, biased_selection=biased),
                                train=True)
            codes = np.asarray(out["codes"])
            assert codes.shape == (B, len(sizes))
            for l, K in enumerate(sizes):
                assert codes[:, l].min() >= 0
                assert codes[:, l].max() < K


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 48), st.integers(2, 12), st.integers(0, 2 ** 16))
def test_property_residual_norm_nonincreasing(B, d, seed):
    """With a zero code available in every layer, the Eq. 9 argmin
    cascade can never increase the residual norm: ``||r - C[k]|| =
    min_j ||r - C_j|| <= ||r - 0||``."""
    rng = np.random.default_rng(seed)
    h = rng.normal(size=(B, d)).astype(np.float32)
    sizes = (6, 4)
    cfg = RQConfig(codebook_sizes=sizes, hist_len=4)
    params, _, state = RQ.init_rq(jax.random.key(seed % 89), cfg, d)
    books = {f"layer{l}": np.asarray(params["codebooks"][f"layer{l}"],
                                     np.float32).copy()
             for l in range(len(sizes))}
    for l in range(len(sizes)):
        books[f"layer{l}"][0] = 0.0
    params = {"codebooks": {k: jnp.asarray(v) for k, v in books.items()}}
    out = RQ.rq_forward(params, state, jnp.asarray(h), cfg, train=False)
    codes = np.asarray(out["codes"])
    resid = h.copy()
    prev = np.linalg.norm(resid, axis=1)
    for l in range(len(sizes)):
        resid = resid - books[f"layer{l}"][codes[:, l]]
        cur = np.linalg.norm(resid, axis=1)
        assert (cur <= prev + 1e-5).all(), (l, cur.max(), prev.min())
        prev = cur


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 40), st.integers(1, 9), st.integers(0, 2 ** 16))
def test_property_codes_utilization_bounds(n, K, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, K, size=(n, 2))
    util = RQ.codes_utilization(codes, (K, K))
    for l, u in enumerate(util):
        assert 0.0 <= u <= 1.0
        if n == 0:
            assert u == 0.0            # exactly 0 only for no assignments
        else:
            assert u >= 1.0 / K
            assert u == len(np.unique(codes[:, l])) / K


def test_codes_utilization_edge_cases():
    """Empty corpus, single row, 1-D codes, single-code and degenerate
    codebooks — every edge the publication gate can meet."""
    assert RQ.codes_utilization(np.zeros((0, 2), np.int32),
                                (8, 4)) == [0.0, 0.0]
    assert RQ.codes_utilization(np.array([[3, 1]]), (8, 4)) == \
        [1.0 / 8, 1.0 / 4]
    assert RQ.codes_utilization(np.array([2, 2, 5]), (8,)) == [2.0 / 8]
    assert RQ.codes_utilization(np.zeros((3, 1), np.int32), (1,)) == [1.0]
    assert RQ.codes_utilization(np.zeros((3, 1), np.int32), (0,)) == [0.0]


def test_per_code_counts_edge_cases():
    counts = RQ.per_code_counts(np.array([[0, 1], [0, 3], [2, 1]]), (4, 4))
    np.testing.assert_array_equal(counts[0], [2, 0, 1, 0])
    np.testing.assert_array_equal(counts[1], [0, 2, 0, 1])
    empty = RQ.per_code_counts(np.zeros((0, 2), np.int64), (3, 2))
    np.testing.assert_array_equal(empty[0], np.zeros(3))
    assert RQ.per_code_counts(np.zeros((2, 1), np.int64), (0,))[0].size == 0


# ---------------------------------------------------------------------------
# dead-code reset: the self-healing pass
# ---------------------------------------------------------------------------

def _reset_setup(sizes, d=6, n=60, seed=0):
    cfg = RQConfig(codebook_sizes=sizes, hist_len=4, dead_floor=0.25)
    params, _, state = RQ.init_rq(jax.random.key(seed), cfg, d)
    h = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    return cfg, params, state, h


def test_dead_code_reset_live_rows_bit_unchanged():
    cfg, params, state, h = _reset_setup((8, 4))
    usage = [np.array([5, 5, 5, 0, 0, 5, 5, 5], np.float32),
             np.array([1, 1, 0, 1], np.float32)]
    new_params, new_state, rep = RQ.dead_code_reset(
        params, state, h, cfg, seed=7, usage=usage)
    assert rep == {"reset_layer0": 2, "reset_layer1": 1}
    for l, dead in ((0, [3, 4]), (1, [2])):
        before = np.asarray(params["codebooks"][f"layer{l}"])
        after = np.asarray(new_params["codebooks"][f"layer{l}"])
        live = np.setdiff1d(np.arange(cfg.codebook_sizes[l]), dead)
        np.testing.assert_array_equal(before[live], after[live])
        assert not np.array_equal(before[dead], after[dead])
        # revived usage restarts at the live mean: not instantly dead
        u = np.asarray(new_state.usage[l])
        assert (u >= cfg.dead_floor / cfg.codebook_sizes[l] - 1e-7).all()
    # histograms / ring pointers ride through untouched
    for a, b in zip(state.hists, new_state.hists):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(new_state.ptr) == int(state.ptr)


def test_dead_code_reset_moves_assignments_only_to_revived():
    """Live rows are bit-unchanged, so any probe point whose argmin
    assignment changes can only have moved TO a revived code (the
    intended split of an overloaded cluster) — never been reshuffled
    between two live codes."""
    cfg, params, state, h = _reset_setup((8,), n=80, seed=1)
    usage = [np.array([1, 1, 1, 1, 1, 0, 0, 0], np.float32)]
    dead = {5, 6, 7}

    def assign(C):
        d2 = (np.sum(h * h, axis=1, keepdims=True) - 2.0 * h @ C.T
              + np.sum(C * C, axis=1)[None, :])
        return d2.argmin(axis=1)

    before = assign(np.asarray(params["codebooks"]["layer0"]))
    new_params, _, rep = RQ.dead_code_reset(params, state, h, cfg,
                                            seed=11, usage=usage)
    assert rep["reset_layer0"] == 3
    after = assign(np.asarray(new_params["codebooks"]["layer0"]))
    live_members = np.flatnonzero(~np.isin(before, list(dead)))
    moved = live_members[before[live_members] != after[live_members]]
    assert len(moved) > 0                  # the reset actually split load
    # a live code's member is never reshuffled to another live code —
    # it either stays or is stolen by a revived row (the intended split)
    assert set(after[moved].tolist()) <= dead


def test_dead_code_reset_bit_deterministic():
    cfg, params, state, h = _reset_setup((8, 4), seed=2)
    usage = [np.array([9, 0, 9, 0, 9, 0, 9, 0], np.float32),
             np.array([1, 0, 1, 0], np.float32)]
    a1, s1, r1 = RQ.dead_code_reset(params, state, h, cfg, seed=5,
                                    step=3, usage=usage)
    a2, s2, r2 = RQ.dead_code_reset(params, state, h, cfg, seed=5,
                                    step=3, usage=usage)
    assert r1 == r2
    for l in range(2):
        np.testing.assert_array_equal(
            np.asarray(a1["codebooks"][f"layer{l}"]),
            np.asarray(a2["codebooks"][f"layer{l}"]))
        np.testing.assert_array_equal(np.asarray(s1.usage[l]),
                                      np.asarray(s2.usage[l]))
    # a different (seed, step) key draws different reseeds
    a3, _, _ = RQ.dead_code_reset(params, state, h, cfg, seed=6,
                                  step=3, usage=usage)
    assert not np.array_equal(np.asarray(a1["codebooks"]["layer0"]),
                              np.asarray(a3["codebooks"]["layer0"]))


def test_dead_code_reset_noop_cases():
    cfg, params, state, h = _reset_setup((4,), n=20, seed=3)
    # all codes live -> no-op
    p1, _, r1 = RQ.dead_code_reset(params, state, h, cfg, seed=0,
                                   usage=[np.ones(4, np.float32)])
    assert r1 == {"reset_layer0": 0}
    np.testing.assert_array_equal(np.asarray(p1["codebooks"]["layer0"]),
                                  np.asarray(params["codebooks"]["layer0"]))
    # all codes dead -> no donors -> no-op (never trades the whole book)
    p2, _, r2 = RQ.dead_code_reset(params, state, h, cfg, seed=0,
                                   usage=[np.zeros(4, np.float32)])
    assert r2 == {"reset_layer0": 0}
    # empty probe -> no-op
    p3, _, r3 = RQ.dead_code_reset(
        params, state, np.zeros((0, 6), np.float32), cfg, seed=0,
        usage=[np.array([1, 0, 1, 0], np.float32)])
    assert r3 == {"reset_layer0": 0}
