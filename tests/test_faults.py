"""Unit tests for the deterministic fault-injection layer
(``repro.faults``): schedule determinism, the four actions, the injector
facade, checksum-detectable corruption, and obs-trace visibility."""
import json
import os

import numpy as np
import pytest

from repro.faults import (FaultInjector, FaultPlan, FaultSpec,
                          InjectedCrash, InjectedFault, clear_plan,
                          corrupt_file, get_faults, install_plan)
from repro.obs import FixedClock, MemorySink, Telemetry


def _tel():
    return Telemetry(sink=MemorySink(), clock=FixedClock())


# ------------------------------------------------------------- schedule


def test_explicit_occurrence_targeting():
    plan = FaultPlan(0, [FaultSpec("s", "raise", occurrences=(1, 3))],
                     telemetry=_tel())
    hits = []
    for i in range(5):
        try:
            plan.fire("s")
            hits.append(False)
        except InjectedFault:
            hits.append(True)
    assert hits == [False, True, False, True, False]
    assert [(r["site"], r["occurrence"]) for r in plan.log] == \
        [("s", 1), ("s", 3)]


def test_occurrence_counters_are_per_site():
    plan = FaultPlan(0, [FaultSpec("b", "raise", occurrences=(0,))],
                     telemetry=_tel())
    plan.fire("a")          # does not advance site b
    plan.fire("a")
    with pytest.raises(InjectedFault):
        plan.fire("b")
    assert plan.occurrence("a") == 2
    assert plan.occurrence("b") == 1


def test_probabilistic_schedule_is_seed_deterministic():
    def run(seed):
        plan = FaultPlan(seed, [FaultSpec("s", "raise", prob=0.3,
                                          max_injections=1 << 30)],
                         telemetry=_tel())
        out = []
        for _ in range(64):
            try:
                plan.fire("s")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b = run(7), run(7)
    assert a == b                      # bit-reproducible schedule
    assert run(8) != a                 # and seed-sensitive
    assert 1 <= sum(a) <= 40           # Bernoulli(0.3) actually fires


def test_max_injections_caps_a_spec():
    plan = FaultPlan(0, [FaultSpec("s", "raise", prob=1.0,
                                   max_injections=2)], telemetry=_tel())
    n = 0
    for _ in range(6):
        try:
            plan.fire("s")
        except InjectedFault:
            n += 1
    assert n == 2


def test_crash_action_raises_injected_crash():
    plan = FaultPlan(0, [FaultSpec("s", "crash", occurrences=(0,))],
                     telemetry=_tel())
    with pytest.raises(InjectedCrash):
        plan.fire("s")
    # InjectedCrash is an InjectedFault — but retry machinery must
    # single it out by the subclass
    assert issubclass(InjectedCrash, InjectedFault)


def test_delay_action_uses_injected_sleeper():
    slept = []
    plan = FaultPlan(0, [FaultSpec("s", "delay", occurrences=(0,),
                                   delay_s=2.5)],
                     telemetry=_tel(), sleep=slept.append)
    spec = plan.fire("s")
    assert spec is not None and slept == [2.5]


def test_unknown_action_rejected():
    with pytest.raises(ValueError, match="unknown fault action"):
        FaultSpec("s", "explode")


# ---------------------------------------------------------- corruption


def test_corrupt_file_flips_bytes_deterministically(tmp_path):
    p = tmp_path / "leaf.npy"
    payload = bytes(range(256)) * 8
    p.write_bytes(payload)
    n = corrupt_file(str(p), (0, 1, 2))
    assert n > 0
    mutated = p.read_bytes()
    assert mutated != payload and len(mutated) == len(payload)
    # deterministic in the key: same key -> same offsets -> XOR back
    corrupt_file(str(p), (0, 1, 2))
    assert p.read_bytes() == payload
    # header region is spared on large files
    assert mutated[:128] == payload[:128]


def test_corrupt_action_targets_the_passed_path(tmp_path):
    p = tmp_path / "f.bin"
    p.write_bytes(b"\x00" * 512)
    plan = FaultPlan(3, [FaultSpec("s", "corrupt", occurrences=(0,))],
                     telemetry=_tel())
    plan.fire("s", path=str(p))
    assert p.read_bytes() != b"\x00" * 512
    # missing path: injection is recorded but nothing explodes
    plan2 = FaultPlan(3, [FaultSpec("s", "corrupt", occurrences=(0,))],
                      telemetry=_tel())
    plan2.fire("s", path=str(tmp_path / "nope.bin"))
    assert len(plan2.log) == 1


# ------------------------------------------------------------ injector


def test_injector_disabled_is_noop_and_install_is_visible_in_place():
    inj = FaultInjector()
    assert not inj.active
    assert inj.fire("anything") is None      # no plan: free pass
    plan = FaultPlan(0, [FaultSpec("s", "raise", occurrences=(0,))],
                     telemetry=_tel())
    inj.install(plan)                        # mutates in place
    assert inj.active
    with pytest.raises(InjectedFault):
        inj.fire("s")
    inj.clear()
    assert inj.fire("s") is None


def test_global_injector_install_and_clear():
    try:
        assert not get_faults().active
        plan = install_plan(FaultPlan(
            0, [FaultSpec("s", "raise", occurrences=(0,))],
            telemetry=_tel()))
        assert get_faults().plan is plan
        with pytest.raises(InjectedFault):
            get_faults().fire("s")
    finally:
        clear_plan()
    assert not get_faults().active


# ------------------------------------------------------- obs visibility


def test_every_injection_emits_span_and_counters():
    tel = _tel()
    sink = tel._sink
    plan = FaultPlan(0, [FaultSpec("s", "raise", occurrences=(0, 1))],
                     telemetry=tel)
    for _ in range(2):
        with pytest.raises(InjectedFault):
            plan.fire("s")
    spans = [json.loads(l) for l in sink.lines]
    fi = [s for s in spans
          if s["type"] == "span" and s["name"] == "fault.injected"]
    assert [(f["attrs"]["site"], f["attrs"]["occurrence"],
             f["attrs"]["action"]) for f in fi] == \
        [("s", 0, "raise"), ("s", 1, "raise")]
    c = tel.snapshot()["counters"]
    assert c["faults.injected"] == 2.0
    assert c["faults.raise"] == 2.0


def test_on_inject_seam_sees_the_record(tmp_path):
    sentinel = tmp_path / "fired"
    plan = FaultPlan(
        0, [FaultSpec("s", "delay", occurrences=(0,), delay_s=0.0)],
        telemetry=_tel(), sleep=lambda s: None,
        on_inject=lambda rec: sentinel.write_text(json.dumps(rec)))
    plan.fire("s")
    rec = json.loads(sentinel.read_text())
    assert rec == dict(site="s", occurrence=0, action="delay", seed=0)
