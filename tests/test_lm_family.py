"""LM family behaviour: decode/prefill consistency, RoPE, chunked attn."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig
from repro.models.lm import model as LM


CFGS = {
    "dense": LMConfig(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=97, norm="layernorm_np",
                      dtype="float32", param_dtype="float32"),
    "gemma": LMConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                      head_dim=32, d_ff=128, vocab_size=64, act="gelu",
                      norm="rmsnorm_p1", tie_embeddings=True,
                      dtype="float32", param_dtype="float32"),
    "moe": LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                    d_ff=64, moe_d_ff=64, vocab_size=50, n_experts=4,
                    n_experts_per_tok=2, capacity_factor=8.0,
                    dtype="float32", param_dtype="float32"),
}


@pytest.mark.parametrize("name", list(CFGS))
def test_prefill_decode_matches_forward(name):
    cfg = CFGS[name]
    params, _ = LM.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                              cfg.vocab_size)
    logits_full, _ = LM.forward(params, cfg, toks)
    last, caches = LM.prefill(params, cfg, toks, block_q=8)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, -1]),
                               rtol=3e-4, atol=3e-4)
    caches = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 16), (0, 0), (0, 0))),
        caches)
    nxt = jnp.argmax(last, -1)[:, None]
    dec, caches = LM.decode_step(params, cfg, nxt, caches, 16)
    logits2, _ = LM.forward(params, cfg,
                            jnp.concatenate([toks, nxt], axis=1))
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(logits2[:, -1]),
                               rtol=3e-3, atol=3e-3)


def test_chunked_attention_block_size_invariance():
    cfg = CFGS["dense"]
    params, _ = LM.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(2), (2, 24), 0, 97)
    outs = []
    for bq in (4, 8, 24):
        logits, _ = LM.forward(params, cfg, toks, block_q=bq)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


def test_unrolled_chunks_match_scan():
    cfg = dc.replace(CFGS["dense"], unroll_chunks=True,
                     scan_layers=False)
    cfg_scan = CFGS["dense"]
    p_scan, _ = LM.init_params(jax.random.key(0), cfg_scan)
    p_unroll, _ = LM.init_params(jax.random.key(0), cfg)
    # same init: unstack scan params into the list layout
    p_unroll = dict(p_unroll)
    p_unroll["layers"] = [jax.tree.map(lambda a: a[i], p_scan["layers"])
                          for i in range(cfg.n_layers)]
    p_unroll["embed"] = p_scan["embed"]
    p_unroll["final_norm"] = p_scan["final_norm"]
    if "lm_head" in p_scan:
        p_unroll["lm_head"] = p_scan["lm_head"]
    toks = jax.random.randint(jax.random.key(3), (2, 16), 0, 97)
    l1 = LM.lm_loss(p_scan, cfg_scan, toks, block_q=8)
    l2 = LM.lm_loss(p_unroll, cfg, toks, block_q=8)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-5)


def test_rope_rotation_properties():
    x = jax.random.normal(jax.random.key(0), (1, 4, 2, 16))
    pos = jnp.arange(4)[None, :]
    y = LM.apply_rope(x, pos, 10000.0)
    # norms preserved
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 16))
    def dot(m, n):
        qm = LM.apply_rope(q, jnp.full((1, 1), m), 10000.0)
        kn = LM.apply_rope(k, jnp.full((1, 1), n), 10000.0)
        return float(jnp.sum(qm * kn))
    np.testing.assert_allclose(dot(3, 1), dot(7, 5), rtol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = dc.replace(CFGS["moe"], capacity_factor=0.25)
    params, _ = LM.init_params(jax.random.key(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    from repro.distributed.sharding import ShardingCtx
    x = jax.random.normal(jax.random.key(1), (2, 64, 32))
    out_low, _ = LM._moe_scatter(lp, cfg, x, ShardingCtx())
    out_hi, _ = LM._moe_scatter(lp, dc.replace(cfg, capacity_factor=8.0),
                                x, ShardingCtx())
    # dropping must change outputs for some tokens
    assert float(jnp.abs(out_low - out_hi).max()) > 1e-5


def test_router_aux_loss_balances():
    cfg = CFGS["moe"]
    params, _ = LM.init_params(jax.random.key(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    xt = jax.random.normal(jax.random.key(1), (256, 32))
    _, _, aux = LM._router(lp, cfg, xt)
    assert float(aux) >= cfg.router_aux_coef * 0.9   # >= coef at balance
