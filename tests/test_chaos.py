"""Chaos tier (``pytest -m chaos``): seeded fault schedules against the
full lifecycle.

Each test drives :func:`repro.faults.chaos.run_chaos` — a 6-cycle
refresh/train/publish/swap/serve loop with faults injected at every
site from the acceptance list — and asserts the four fault-tolerance
invariants plus bit-reproducibility of the whole report.
"""
import json
import os

import pytest

from repro.faults.chaos import REQUIRED_SITES, default_specs, run_chaos

pytestmark = pytest.mark.chaos

#: the CI seed matrix — ci.yml shards one seed per job via CHAOS_SEEDS
SEEDS = tuple(int(s) for s in
              os.environ.get("CHAOS_SEEDS", "0,1,2").split(","))


@pytest.fixture(scope="module")
def reports(tmp_path_factory):
    """One chaos run per seed, shared across the invariant tests."""
    out = {}
    for seed in SEEDS:
        d = tmp_path_factory.mktemp(f"chaos_seed{seed}")
        out[seed] = run_chaos(seed, snapshot_dir=str(d))
    return out


@pytest.mark.parametrize("seed", SEEDS)
def test_all_required_sites_injected(reports, seed):
    rep = reports[seed]
    assert set(rep["sites_injected"]) >= set(REQUIRED_SITES), \
        f"schedule missed sites: {set(REQUIRED_SITES) - set(rep['sites_injected'])}"
    # the standard schedule places one injection per spec
    assert len(rep["injected"]) == len(default_specs())


@pytest.mark.parametrize("seed", SEEDS)
def test_no_torn_or_corrupt_snapshot_served(reports, seed):
    rep = reports[seed]
    assert rep["invariants"]["no_bad_serve"], \
        (rep["served_versions"], rep["good_versions"])


@pytest.mark.parametrize("seed", SEEDS)
def test_recall_never_below_last_good_floor(reports, seed):
    rep = reports[seed]
    assert rep["invariants"]["recall_floor"], rep["recall_by_served"]


@pytest.mark.parametrize("seed", SEEDS)
def test_exactly_once_events_across_crash_recovery(reports, seed):
    rep = reports[seed]
    assert rep["invariants"]["exactly_once"], \
        f"{rep['duplicates']} duplicated ring events"


@pytest.mark.parametrize("seed", SEEDS)
def test_every_injected_fault_is_traced(reports, seed):
    rep = reports[seed]
    assert rep["invariants"]["all_faults_traced"], rep["injected"]


@pytest.mark.parametrize("seed", SEEDS)
def test_crash_recovery_actually_exercised(reports, seed):
    """The standard schedule includes one crash; recovery must resume
    serving from the last good on-disk version."""
    rep = reports[seed]
    assert rep["crashes"] == 1 and rep["recoveries"] == 1
    crashed = [c for c in rep["cycle_log"] if c.get("crashed")]
    assert crashed and crashed[0]["recovered_version"] in \
        rep["good_versions"]
    # the corrupt-on-load fault forces the fallback walk + quarantine
    assert rep["counters"].get("snapshot.corrupt_detected", 0) >= 1
    assert rep["counters"].get("snapshot.quarantined", 0) >= 1


@pytest.mark.parametrize("seed", SEEDS)
def test_degradation_and_rollback_paths_hit(reports, seed):
    rep = reports[seed]
    c = rep["counters"]
    assert c.get("lifecycle.rollbacks", 0) >= 1
    assert c.get("lifecycle.recoveries", 0) >= 1
    assert c.get("lifecycle.stage_retries", 0) >= 1
    assert c.get("swap.ingest_shed_batches", 0) >= 1


def test_report_is_bit_reproducible(tmp_path):
    """Acceptance bar: two same-seed runs (distinct snapshot dirs)
    produce byte-identical reports."""
    a = run_chaos(0, snapshot_dir=str(tmp_path / "a"))
    b = run_chaos(0, snapshot_dir=str(tmp_path / "b"))
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_seeds_differ():
    """Different seeds produce different traffic/delta streams (sanity
    that determinism isn't 'ignores the seed')."""
    import numpy as np

    from repro.faults.chaos import _make_delta
    d0 = _make_delta(0, 1, 0.0, 50, 60)
    d1 = _make_delta(1, 1, 0.0, 50, 60)
    assert not np.array_equal(d0.user_id, d1.user_id)
