"""Device-resident serving engine vs the preserved host engine.

The device ``ClusterQueueStore`` (MVCC snapshots + one jitted dispatch
per request batch) must be an observably identical replacement for the
seqlock ``HostQueueStore`` on every retrieval it serves.  For
non-decreasing-timestamp streams the contract is *bitwise* equality —
pinned here across seeds, ring wraps, dup-heavy streams, unknown and
post-snapshot user ids, recency-cutoff edges, and empty queues — in
direct mode, delta (LSM) mode, and through the sharded router.

The one documented tolerance: the engines dedup at different times
(device at ingest, latest-ingest-wins; host at retrieve,
newest-timestamp-wins), so a duplicate ``(cluster, item)`` re-ingested
in a *later batch* with an *older timestamp* diverges iff the recency
cutoff falls between the two timestamps.  That exact window is pinned
below too.
"""
import numpy as np
import pytest

import jax

from repro.core.serving import (ClusterQueueStore, HostQueueStore,
                                ServingCostModel, ShardedQueueStore,
                                u2i2i_retrieve_batch)
from repro.obs.telemetry import Telemetry


# ---------------------------------------------------------------------------
# stream + comparison helpers
# ---------------------------------------------------------------------------

N_USERS, N_CLUSTERS, N_ITEMS = 32, 6, 10      # tiny item space: dup-heavy


def _clusters(rng):
    return rng.integers(0, N_CLUSTERS, N_USERS).astype(np.int64)


def _batches(rng, n_batches, t0=0.0, span=10.0, id_hi=N_USERS + 4):
    """Batched event stream with globally non-decreasing timestamps.
    ``id_hi`` past the table size mixes in post-snapshot (unknown) ids;
    empty batches exercise the no-op ingest path."""
    out, t = [], t0
    for b in range(n_batches):
        n = int(rng.integers(0, 40))          # 0 => empty-batch edge
        u = rng.integers(0, id_hi, n)
        it = rng.integers(0, N_ITEMS, n)
        ts = t + np.sort(rng.random(n)) * span
        t += span
        out.append((u, it, ts))
    return out


# probe users: known, repeated, never-ingested clusters, post-snapshot
# ids, and a negative id — every row class the engines must agree on
_PROBES = np.array([0, 1, 1, 5, 17, 31, N_USERS, N_USERS + 9, -1])


def _assert_parity(dev, host, now, ks=(4, 8)):
    for k in ks:
        np.testing.assert_array_equal(
            dev.retrieve_batch(_PROBES, now, k),
            host.retrieve_batch(_PROBES, now, k))
    np.testing.assert_array_equal(dev.cursor, host.cursor)


def _run_stream_parity(dev, host, rng):
    """Ingest the same stream into both engines, checking parity after
    every batch at recency-edge ``now`` values (cutoff before, inside,
    and after the retained window)."""
    for u, it, ts in _batches(rng, 7):
        dev.ingest(u, it, ts)
        host.ingest(u, it, ts)
        t_end = float(ts[-1]) if ts.size else 70.0
        for now in (t_end, t_end + 25.0, t_end + 49.9, t_end + 200.0):
            _assert_parity(dev, host, now)


@pytest.mark.parametrize("seed", range(4))
def test_direct_mode_matches_host_bitwise(seed):
    rng = np.random.default_rng(seed)
    flat = _clusters(rng)
    # queue_len 8 << events per cluster: every cluster wraps repeatedly
    dev = ClusterQueueStore(flat, queue_len=8, recency_s=50.0)
    host = HostQueueStore(flat, queue_len=8, recency_s=50.0)
    _run_stream_parity(dev, host, rng)


@pytest.mark.parametrize("seed", range(3))
def test_delta_mode_matches_host_bitwise(seed):
    """LSM writes: small ``delta_cap`` forces mid-stream folds; reads
    that see a part-filled delta must still match the host."""
    rng = np.random.default_rng(100 + seed)
    flat = _clusters(rng)
    dev = ClusterQueueStore(flat, queue_len=8, recency_s=50.0,
                            delta_cap=16)
    host = HostQueueStore(flat, queue_len=8, recency_s=50.0)
    _run_stream_parity(dev, host, rng)


@pytest.mark.parametrize("seed", range(3))
def test_sharded_router_matches_host_bitwise(seed):
    """3 shards over 6 clusters: scatter-ingest + gather-merge retrieve
    must be transparent — bitwise equal to the unsharded host."""
    rng = np.random.default_rng(200 + seed)
    flat = _clusters(rng)
    dev = ShardedQueueStore(flat, n_shards=3, queue_len=8,
                            recency_s=50.0)
    host = HostQueueStore(flat, queue_len=8, recency_s=50.0)
    assert len(dev.partitions()) == 3
    _run_stream_parity(dev, host, rng)


def test_empty_store_unknown_users_and_retrieve_list_api():
    flat = _clusters(np.random.default_rng(0))
    dev = ClusterQueueStore(flat, queue_len=8, recency_s=50.0)
    host = HostQueueStore(flat, queue_len=8, recency_s=50.0)
    # nothing ingested: every row is all -1 on both engines
    _assert_parity(dev, host, now=10.0)
    assert (dev.retrieve_batch(_PROBES, 10.0, 4) == -1).all()
    dev.ingest(np.array([0]), np.array([3]), np.array([1.0]))
    host.ingest(np.array([0]), np.array([3]), np.array([1.0]))
    assert dev.retrieve(0, 2.0, 4) == host.retrieve(0, 2.0, 4)
    assert dev.retrieve(N_USERS + 1, 2.0, 4) == []   # post-snapshot id


def test_ts_regression_cross_batch_is_the_documented_tolerance():
    """The one permitted divergence, pinned to its exact window: a
    duplicate re-ingested in a later batch with an older timestamp.
    Device keeps the re-ingested (older) stamp, host keeps the newest;
    they disagree iff the cutoff lands between the two stamps."""
    flat = np.zeros(1, np.int64)
    dev = ClusterQueueStore(flat, queue_len=8, recency_s=50.0)
    host = HostQueueStore(flat, queue_len=8, recency_s=50.0)
    for s in (dev, host):
        s.ingest(np.array([0]), np.array([7]), np.array([10.0]))
        s.ingest(np.array([0]), np.array([7]), np.array([5.0]))  # older!
    u = np.array([0])
    # cutoff below both stamps (now=54 -> cutoff 4): both return it
    np.testing.assert_array_equal(dev.retrieve_batch(u, 54.0, 4),
                                  host.retrieve_batch(u, 54.0, 4))
    # cutoff between the stamps (now=57 -> cutoff 7): the divergence
    assert host.retrieve_batch(u, 57.0, 4)[0, 0] == 7
    assert (dev.retrieve_batch(u, 57.0, 4) == -1).all()
    # cutoff above both (now=61 -> cutoff 11): both empty again
    np.testing.assert_array_equal(dev.retrieve_batch(u, 61.0, 4),
                                  host.retrieve_batch(u, 61.0, 4))


def _ingest_both(stores, rng, n_batches=5):
    for u, it, ts in _batches(rng, n_batches):
        for s in stores:
            s.ingest(u, it, ts)


def test_fused_serve_matches_host_u2i2i():
    """The single-dispatch serve (retrieve + U2I2I union in one jit)
    must be bitwise equal to the host's two-step path."""
    rng = np.random.default_rng(7)
    flat = _clusters(rng)
    dev = ClusterQueueStore(flat, queue_len=8, recency_s=1e9)
    shd = ShardedQueueStore(flat, n_shards=2, queue_len=8, recency_s=1e9)
    host = HostQueueStore(flat, queue_len=8, recency_s=1e9)
    _ingest_both((dev, shd, host), rng)
    i2i = rng.integers(0, N_ITEMS, (N_ITEMS, 3)).astype(np.int64)
    hs, hu = host.serve_batch(_PROBES, 100.0, n_recent=4, k=8, i2i=i2i)
    for store in (dev, shd):
        seeds, union = store.serve_batch(_PROBES, 100.0, n_recent=4,
                                         k=8, i2i=i2i)
        np.testing.assert_array_equal(seeds, hs)
        np.testing.assert_array_equal(union, hu)
        np.testing.assert_array_equal(
            union, u2i2i_retrieve_batch(i2i, seeds, 8))
    # no i2i table: seeds only, union all -1
    seeds, union = dev.serve_batch(_PROBES, 100.0, n_recent=4, k=8)
    np.testing.assert_array_equal(seeds, hs)
    assert (union == -1).all()


def test_kernel_serve_path_matches_fused():
    """``use_kernel=True`` routes the device store's ring view through
    the fused Pallas ``queue_gather`` kernel — same answers."""
    rng = np.random.default_rng(9)
    flat = _clusters(rng)
    dev = ClusterQueueStore(flat, queue_len=8, recency_s=1e9)
    _ingest_both((dev,), rng)
    i2i = rng.integers(0, N_ITEMS, (N_ITEMS, 3)).astype(np.int64)
    s0, u0 = dev.serve_batch(_PROBES, 100.0, n_recent=4, k=8, i2i=i2i)
    s1, u1 = dev.serve_batch(_PROBES, 100.0, n_recent=4, k=8, i2i=i2i,
                             use_kernel=True)
    np.testing.assert_array_equal(s1, s0)
    np.testing.assert_array_equal(u1, u0)


# ---------------------------------------------------------------------------
# stats, telemetry, cost model, mesh placement
# ---------------------------------------------------------------------------

def test_stats_per_shard_and_delta_pending():
    rng = np.random.default_rng(3)
    flat = _clusters(rng)
    shd = ShardedQueueStore(flat, n_shards=3, queue_len=8,
                            recency_s=1e9, delta_cap=64)
    _ingest_both((shd,), rng, n_batches=3)
    st = shd.stats()
    assert st["n_shards"] == 3.0
    for s in range(3):
        assert f"shard{s}.n_clusters_active" in st
        assert f"shard{s}.mean_queue" in st
    assert sum(st[f"shard{s}.n_clusters_active"] for s in range(3)) \
        == st["n_clusters_active"]
    # folding drains the pending delta
    pending = [p.stats()["delta_pending"] for p in shd.partitions()]
    for p in shd.partitions():
        p._fold()
    assert any(x > 0 for x in pending) or shd.cursor.sum() == 0
    assert all(p.stats()["delta_pending"] == 0.0
               for p in shd.partitions())


def test_sharded_telemetry_tagged_counters_and_gauges():
    """Shards emit ``.shardN``-tagged metrics; the facade emits the
    untagged aggregates — tagged ingest counts must sum to the
    aggregate, and every shard publishes its own depth gauges."""
    rng = np.random.default_rng(5)
    flat = _clusters(rng)
    tel = Telemetry()
    shd = ShardedQueueStore(flat, n_shards=2, queue_len=8,
                            recency_s=1e9, telemetry=tel)
    u = rng.integers(0, N_USERS, 64)
    it = rng.integers(0, N_ITEMS, 64)
    shd.ingest(u, it, np.sort(rng.random(64) * 10.0))
    shd.retrieve_batch(np.arange(8), 20.0, 4)
    snap = tel.snapshot()
    c, g = snap["counters"], snap["gauges"]
    assert c["serving.ingest_events"] == 64.0
    assert (c.get("serving.ingest_events.shard0", 0.0)
            + c.get("serving.ingest_events.shard1", 0.0)) == 64.0
    assert c["serving.retrieve_requests"] == 1.0
    assert "serving.queue_depth_max" in g
    for s in range(2):
        if c.get(f"serving.ingest_events.shard{s}", 0.0):
            assert f"serving.queue_depth_max.shard{s}" in g
    assert snap["hists"]["serving.retrieve_latency_s"].get("n", 0) >= 1


def test_cost_model_shard_and_batch_scaling():
    """Launch overheads scale with the shard count and amortize with
    the dispatch batch; per-request queue work does neither."""
    one = ServingCostModel(batch_size=1, n_shards=1)
    four = ServingCostModel(batch_size=1, n_shards=4)
    per_req_bytes = 8.0 * one.queue_read_items + 8.0
    assert four.cluster_bytes_per_req() - per_req_bytes \
        == pytest.approx(4 * (one.cluster_bytes_per_req()
                              - per_req_bytes))
    assert four.cluster_flops_per_req() > one.cluster_flops_per_req()
    # batching amortizes the extra dispatches away
    assert four.cluster_bytes_per_req(batch_size=256) \
        < one.cluster_bytes_per_req(batch_size=1)
    assert four.cost_reduction(batch_size=256) \
        > four.cost_reduction(batch_size=1)
    assert one.cost_reduction(batch_size=256) > 0.99


def test_mesh_placement_smoke():
    """With a mesh, shard state is placed round-robin over its devices
    and answers are unchanged."""
    rng = np.random.default_rng(13)
    flat = _clusters(rng)
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("shards",))
    shd = ShardedQueueStore(flat, n_shards=2, queue_len=8,
                            recency_s=1e9, mesh=mesh)
    host = HostQueueStore(flat, queue_len=8, recency_s=1e9)
    _ingest_both((shd, host), rng, n_batches=3)
    np.testing.assert_array_equal(
        shd.retrieve_batch(_PROBES, 100.0, 8),
        host.retrieve_batch(_PROBES, 100.0, 8))
    devs = set(np.asarray(mesh.devices).ravel().tolist())
    for p in shd.partitions():
        arr = p._state["items"]
        assert set(arr.devices()) <= devs
